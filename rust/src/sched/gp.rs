//! The graph-partition policy — the paper's contribution (§III).
//!
//! Offline pipeline (paper Fig 2's processing flow):
//!
//! 1. **Weighting** — every node gets its kernel execution time (GPU time
//!    by default, §III's discussed choice), every edge its PCIe transfer
//!    time, both from the performance model (the paper's offline
//!    measurements), in integer microseconds.
//! 2. **Ratio** — per-device workload targets from Formula (1)/(2):
//!    `R_cpu = T_gpu / (T_gpu + T_cpu)`, generalized to k devices by
//!    speed proportionality.
//! 3. **Partition** — the multilevel partitioner (METIS substitute) with
//!    `k = #devices` and the target ratio vector, minimizing edge cut
//!    (transfer time) subject to proportional load balance.
//! 4. **Pinning** — each kernel is pinned to its partition's device; the
//!    runtime "cannot schedule them again" (§III.B). `select` is a table
//!    lookup — the amortized "singular decision" of §IV.D.

use super::{DispatchCtx, Scheduler};
use crate::dag::metis_io::dag_to_builder;
use crate::dag::{Dag, KernelKind, NodeId};
use crate::partition::{partition_with, PartitionConfig, PartitionResult, PartitionWorkspace};
use crate::perfmodel::{edge_weight_us, node_weight_us, NodeWeightPolicy, PerfModel};
use crate::platform::{DeviceId, Platform};

/// Tunables for the offline plan.
#[derive(Debug, Clone)]
pub struct GpConfig {
    /// Which device's kernel time becomes the node weight (§III choice;
    /// GPU time is the paper's default — smaller node weights give edge
    /// weights higher priority during partitioning).
    pub node_weight: NodeWeightPolicy,
    /// Load-imbalance tolerance passed to the partitioner.
    pub epsilon: f64,
    /// Partitioner seed.
    pub seed: u64,
}

impl Default for GpConfig {
    fn default() -> Self {
        GpConfig { node_weight: NodeWeightPolicy::GpuTime, epsilon: 0.05, seed: 1 }
    }
}

/// Offline graph-partition scheduler.
pub struct GraphPartition {
    config: GpConfig,
    parts: Vec<DeviceId>,
    last_result: Option<PartitionResult>,
    ratios: Vec<f64>,
    /// Partitioner scratch, reused across `plan` calls (replanning a
    /// stream of DAGs allocates nothing once buffers are warm).
    workspace: PartitionWorkspace,
}

impl GraphPartition {
    pub fn new(config: GpConfig) -> GraphPartition {
        GraphPartition {
            config,
            parts: Vec::new(),
            last_result: None,
            ratios: Vec::new(),
            workspace: PartitionWorkspace::new(),
        }
    }

    /// The pinned device per node (valid after `plan`).
    pub fn parts(&self) -> &[DeviceId] {
        &self.parts
    }

    /// Partition quality of the last plan.
    pub fn last_result(&self) -> Option<&PartitionResult> {
        self.last_result.as_ref()
    }

    /// Workload ratios used for the last plan (Formula 1/2).
    pub fn ratios(&self) -> &[f64] {
        &self.ratios
    }

    /// Aggregate workload ratios over a whole (possibly heterogeneous)
    /// DAG: `R_d ∝ 1 / T_d` where `T_d` is the total time of running
    /// *every* kernel on device `d`. For the paper's homogeneous tasks
    /// this is exactly Formula (1)/(2).
    pub fn aggregate_ratios(dag: &Dag, platform: &Platform, model: &dyn PerfModel) -> Vec<f64> {
        let k = platform.device_count();
        let mut totals = vec![0.0f64; k];
        for (_, node) in dag.nodes() {
            if node.kernel == KernelKind::Source {
                continue;
            }
            for (d, t) in totals.iter_mut().enumerate() {
                *t += model.kernel_time_ms(node.kernel, node.size, d);
            }
        }
        let inv: Vec<f64> = totals.iter().map(|&t| 1.0 / t.max(1e-12)).collect();
        let sum: f64 = inv.iter().sum();
        inv.iter().map(|i| i / sum).collect()
    }
}

impl Scheduler for GraphPartition {
    fn name(&self) -> &'static str {
        "gp"
    }

    fn plan(&mut self, dag: &Dag, platform: &Platform, model: &dyn PerfModel) {
        let policy = self.config.node_weight;
        let n = dag.node_count();
        let mut builder = dag_to_builder(
            dag,
            |id: NodeId| {
                let node = dag.node(id);
                node_weight_us(model, node.kernel, node.size, platform, policy)
            },
            |eid| edge_weight_us(model, dag.edge(eid).bytes),
        );

        // Host anchor: the paper's zero-weight "empty kernel" (§III.B).
        // All initial data lives on host memory, and results return there;
        // modelling both as edges to a vertex *pinned to the host
        // partition* lets the cut metric see initial-load and write-back
        // transfers, not just inter-kernel ones.
        let anchor = builder.add_vertex(0);
        for (id, node) in dag.nodes() {
            if node.kernel == KernelKind::Source {
                continue;
            }
            let mat_bytes = 4 * node.size as u64 * node.size as u64;
            let mut w = 0i64;
            // Initial inputs not fed by an in-edge.
            let missing = node.kernel.arity().saturating_sub(dag.in_degree(id));
            w += missing as i64 * edge_weight_us(model, mat_bytes);
            // Result write-back for sinks.
            if dag.out_degree(id) == 0 {
                w += edge_weight_us(model, mat_bytes);
            }
            if w > 0 {
                builder.add_edge(anchor, id, w);
            }
        }
        let metis = builder.build();
        let mut fixed = vec![-1i32; n + 1];
        fixed[anchor] = 0; // host partition = device 0's memory node

        self.ratios = Self::aggregate_ratios(dag, platform, model);
        let cfg = PartitionConfig {
            k: platform.device_count(),
            targets: Some(self.ratios.clone()),
            epsilon: self.config.epsilon,
            seed: self.config.seed,
            fixed: Some(fixed),
            ..Default::default()
        };
        let result = partition_with(&metis, &cfg, &mut self.workspace);
        self.parts = result.parts[..n].to_vec();
        self.last_result = Some(result);
    }

    fn select(&mut self, ctx: &DispatchCtx) -> DeviceId {
        // Pure table lookup: the singular offline decision, amortized.
        self.parts[ctx.task]
    }

    fn is_offline(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::generator::{generate_layered, GeneratorConfig};
    use crate::perfmodel::CalibratedModel;

    fn planned(kernel: KernelKind, size: u32) -> GraphPartition {
        let dag = generate_layered(&GeneratorConfig::paper(kernel, size));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut gp = GraphPartition::new(GpConfig::default());
        gp.plan(&dag, &platform, &model);
        gp
    }

    #[test]
    fn mm_large_pins_everything_to_gpu() {
        // Paper §IV.C: "the workload on the CPU is almost 0, while the
        // workload on the GPU is almost 1" for large MM.
        let gp = planned(KernelKind::Mm, 2048);
        let cpu_nodes = gp.parts().iter().filter(|&&p| p == 0).count();
        assert!(cpu_nodes <= 1, "{cpu_nodes} nodes on CPU, expected ~0");
        assert!(gp.ratios()[0] < 0.02);
    }

    #[test]
    fn ma_large_splits_work() {
        // MA's small device gap leaves the CPU a real share.
        let gp = planned(KernelKind::Ma, 2048);
        let cpu_nodes = gp.parts().iter().filter(|&&p| p == 0).count();
        assert!(cpu_nodes >= 2, "CPU should receive some MA kernels, got {cpu_nodes}");
        let gpu_nodes = gp.parts().iter().filter(|&&p| p == 1).count();
        assert!(gpu_nodes > cpu_nodes, "GPU is still faster overall");
    }

    #[test]
    fn ratios_match_formula1() {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let r = GraphPartition::aggregate_ratios(&dag, &platform, &model);
        let t_cpu = model.kernel_time_ms(KernelKind::Ma, 1024, 0);
        let t_gpu = model.kernel_time_ms(KernelKind::Ma, 1024, 1);
        // Homogeneous graph: aggregate == per-kernel Formula (1).
        assert!((r[0] - t_gpu / (t_gpu + t_cpu)).abs() < 1e-9);
    }

    #[test]
    fn select_is_pinned_lookup() {
        let mut gp = planned(KernelKind::Ma, 1024);
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let parts = gp.parts().to_vec();
        // Whatever the dynamic state says, the pin wins.
        for task in 0..parts.len() {
            let free = [999.0, 0.0];
            let ctx = DispatchCtx {
                task,
                kernel: KernelKind::Ma,
                size: 1024,
                ready_ms: 0.0,
                device_free_ms: &free,
                inputs: &[],
                platform: &platform,
                model: &model,
            };
            assert_eq!(gp.select(&ctx), parts[task]);
        }
        assert!(gp.is_offline());
    }

    #[test]
    fn node_weight_policy_changes_plan_inputs() {
        // CPU-time weights are larger; the plan object records the policy.
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 512));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut a = GraphPartition::new(GpConfig {
            node_weight: NodeWeightPolicy::GpuTime,
            ..Default::default()
        });
        let mut b = GraphPartition::new(GpConfig {
            node_weight: NodeWeightPolicy::CpuTime,
            ..Default::default()
        });
        a.plan(&dag, &platform, &model);
        b.plan(&dag, &platform, &model);
        // Both must produce complete pinnings.
        assert_eq!(a.parts().len(), dag.node_count());
        assert_eq!(b.parts().len(), dag.node_count());
    }

    #[test]
    fn tri_device_plan_covers_all_devices_for_ma() {
        let dag = generate_layered(&GeneratorConfig::scaled(200, KernelKind::Ma, 2048, 5));
        let platform = Platform::tri_device();
        let model = CalibratedModel::tri_device();
        let mut gp = GraphPartition::new(GpConfig::default());
        gp.plan(&dag, &platform, &model);
        let mut counts = [0usize; 3];
        for &p in gp.parts() {
            counts[p] += 1;
        }
        assert!(counts[1] > 0, "GPU empty: {counts:?}");
        // The bandwidth-bound kernel leaves meaningful work for ≥2 devices.
        assert!(counts.iter().filter(|&&c| c > 0).count() >= 2, "{counts:?}");
    }
}
