//! The graph-partition policy — the paper's contribution (§III).
//!
//! Offline pipeline (paper Fig 2's processing flow):
//!
//! 1. **Weighting** — every node gets its kernel execution time (GPU time
//!    by default, §III's discussed choice), every edge its PCIe transfer
//!    time, both from the performance model (the paper's offline
//!    measurements), in integer microseconds.
//! 2. **Ratio** — per-device workload targets from Formula (1)/(2):
//!    `R_cpu = T_gpu / (T_gpu + T_cpu)`, generalized to k devices by
//!    speed proportionality.
//! 3. **Partition** — the multilevel partitioner (METIS substitute) with
//!    `k = #devices` and the target ratio vector, minimizing edge cut
//!    (transfer time) subject to proportional load balance.
//! 4. **Pinning** — each kernel is pinned to its partition's device; the
//!    runtime "cannot schedule them again" (§III.B). `select` is a table
//!    lookup — the amortized "singular decision" of §IV.D.
//!
//! The pipeline's product is an immutable [`Plan`]
//! ([`Planner::build_plan`]); [`Scheduler::on_submit`] installs a plan —
//! freshly built or served from a [`crate::sched::PlanCache`] — under the
//! submitting job's [`JobId`], so many jobs can be pinned and in flight
//! simultaneously (the open-system engine).
//!
//! # Windowed replanning (`GpConfig::window`)
//!
//! The paper concedes that gp "makes a singular decision and uses the
//! same decision for all following tasks" (§IV.D). With `window = W` the
//! policy attacks exactly that: every `W` task completions
//! ([`Scheduler::on_task_finish`]) it re-partitions the
//! not-yet-dispatched **union frontier of every in-flight job** — one
//! merged graph holding each admitted job's undispatched vertices plus a
//! single shared host anchor — pinning already-dispatched tasks to their
//! devices (their data is already placed) and recomputing the Formula
//! (1)/(2) ratios over the union's *remaining* kernels only. With one
//! job in flight this degenerates to PR 2's per-job frontier replan
//! bit-for-bit; with several, the partitioner balances the devices
//! across job boundaries — e.g. a fresh job's compute-bound stage is
//! weighed against an old job's draining bandwidth-bound tail, which a
//! per-job plan cannot see. Weights are snapshotted at submit, so
//! replanning needs no model access and stays allocation-light through
//! the reused [`PartitionWorkspace`].
//!
//! ## Incremental replanning (`GpConfig::incremental`, default on)
//!
//! Steady-state replans do not start from scratch. The policy keeps a
//! **frontier epoch** that is bumped by every event changing the union
//! frontier (admission, first dispatch of a task, drain, kill, device
//! up/down), and at each replan:
//!
//! * **No-change fast exit** — if the epoch is unchanged since the last
//!   replan, the merged graph and pins are identical, so the previous
//!   (deterministic) result still stands: the replan is skipped
//!   outright and counted in [`crate::sched::ReplanStats::skipped`].
//! * **Warm start** — otherwise the previous per-job pin tables are
//!   scattered into a warm assignment over the merged graph — jobs
//!   that never went through a merged replan scatter
//!   [`crate::partition::WARM_FREE`] instead, because their solo
//!   per-job plan ignored the rest of the system — and
//!   [`crate::partition::partition_warm_with`] greedily places the
//!   free vertices, then runs one direct boundary refinement pass at
//!   the fine level (FM with rollback at `k == 2`, a greedy k-way
//!   pass otherwise; no coarsening hierarchy, no recursive
//!   bisection), repairing the plan around the diff instead of
//!   re-deriving it. Device failures and forced recovery replans bump
//!   the epoch *before* replanning, so they always run.
//!
//! Replans of **both** arms use *backlog-aware* targets rather than
//! raw Formula (1)/(2) over the remaining work: `select` snapshots the
//! engine's per-device free-horizon estimate, and the replan solves
//! `backlog_d + share_d / speed_d = const` under `Σ share = 1` so
//! every device is projected to finish together — a device running
//! behind receives less new frontier work, an idle (or freshly
//! recovered) one more. The snapshot is relative, so the absolute
//! clock offset cancels and no "now" timestamp is needed.
//!
//! With `incremental=0` every replan takes the from-scratch multilevel
//! path ([`crate::partition::partition_with`]) — the reference arm the
//! benches compare against. Cumulative effort (run/skipped counts,
//! wall-clock nanoseconds) is reported through
//! [`Scheduler::replan_stats`] and lands in the session reports as
//! `replans` / `replan_cost_ms`.
//!
//! Windowed decisions depend on *when* `on_task_finish` fires: the
//! simulator delivers completions in dispatch order, the real engine in
//! true completion order, so — unlike every offline policy — windowed
//! gp's assignments are pinned per engine, not across engines (the
//! golden and bench suites exercise the simulator).
//!
//! # Recovery (device failures)
//!
//! Windowed gp is the one policy that *replans* around elasticity
//! events instead of merely re-enqueueing: [`Scheduler::on_task_killed`]
//! returns a killed task to the union frontier (its dispatched bit is
//! cleared, and a drained-but-revoked job re-enters the frontier), and
//! [`Scheduler::on_device_down`] / [`Scheduler::on_device_up`] force an
//! immediate frontier replan so the partitioner sees the shifted device
//! balance right away — the "recovery-aware replanning" arm of the
//! fault benchmarks. One-shot gp (and every other policy) takes the
//! default no-op hooks and falls back to plain re-enqueue.

use std::sync::Arc;

use super::{plan, DispatchCtx, JobId, Plan, Planner, ReplanStats, Scheduler};
use crate::dag::metis_io::{dag_to_builder, CsrBuilder};
use crate::dag::{Dag, KernelKind, NodeId};
use crate::partition::{
    partition_warm_with, partition_with, PartitionConfig, PartitionResult, PartitionWorkspace,
    WARM_FREE,
};
use crate::perfmodel::{edge_weight_us, node_weight_us, NodeWeightPolicy, PerfModel};
use crate::platform::{DeviceId, Platform};

/// Tunables for the offline plan.
#[derive(Debug, Clone)]
pub struct GpConfig {
    /// Which device's kernel time becomes the node weight (§III choice;
    /// GPU time is the paper's default — smaller node weights give edge
    /// weights higher priority during partitioning).
    pub node_weight: NodeWeightPolicy,
    /// Load-imbalance tolerance passed to the partitioner.
    pub epsilon: f64,
    /// Partitioner seed.
    pub seed: u64,
    /// Re-partition the undispatched union frontier every `window`
    /// completions (`None` = the paper's one-shot §IV.D behavior).
    pub window: Option<usize>,
    /// Incremental replans (windowed mode): warm-start refinement from
    /// the previous assignment and skip no-change replans entirely
    /// (see the module docs). `false` = from-scratch multilevel replans
    /// every time, the reference arm.
    pub incremental: bool,
}

impl Default for GpConfig {
    fn default() -> Self {
        GpConfig {
            node_weight: NodeWeightPolicy::GpuTime,
            epsilon: 0.05,
            seed: 1,
            window: None,
            incremental: true,
        }
    }
}

/// Weight snapshot taken at submit so windowed replans need no model.
#[derive(Debug, Clone, Default)]
struct FrontierState {
    /// Node weight (µs) per vertex.
    node_w: Vec<i64>,
    /// Host-anchor edge weight (µs) per vertex (0 = no anchor edge).
    anchor_w: Vec<i64>,
    /// DAG edges as `(src, dst, µs)`.
    edges: Vec<(u32, u32, i64)>,
    /// `kernel_time_ms(v, d)` flattened as `v * k + d`.
    dev_time: Vec<f64>,
    /// Real kernel (not a virtual source)?
    real: Vec<bool>,
    /// Device count.
    k: usize,
}

/// Per-job policy state, indexed by [`JobId`].
#[derive(Debug, Clone, Default)]
struct JobState {
    /// In flight (admitted, not yet drained)? Drained jobs keep their
    /// pin table for inspection but leave the union frontier.
    active: bool,
    /// Has this job been through an executed merged replan? Until then
    /// its pins come from the solo per-job plan, which ignored every
    /// other in-flight job — warm starts scatter such jobs as *free*
    /// vertices ([`crate::partition::WARM_FREE`]) so `warm_place` seeds
    /// them against the union's real balance instead.
    merged: bool,
    /// Pinned device per node.
    parts: Vec<DeviceId>,
    /// Dispatch bitmap (windowed mode only).
    dispatched: Vec<bool>,
    /// Weight snapshot (windowed mode only).
    frontier: FrontierState,
}

/// Offline graph-partition scheduler.
pub struct GraphPartition {
    config: GpConfig,
    /// Per-job state; grows with submissions, entries retire on drain.
    jobs: Vec<JobState>,
    /// Most recently submitted job (target of the inspection accessors).
    current: usize,
    last_result: Option<PartitionResult>,
    ratios: Vec<f64>,
    /// Partitioner scratch, reused across plans and replans (replanning a
    /// stream of DAGs allocates nothing once buffers are warm).
    workspace: PartitionWorkspace,
    /// Last `device_free_ms` snapshot seen by `select` — the engine's
    /// per-device free-horizon estimate. Replans turn it into relative
    /// backlog and equalize projected completion across devices (see
    /// the module docs); the absolute clock offset cancels out, so no
    /// "now" timestamp is needed.
    dev_free_ms: Vec<f64>,
    finishes_since_replan: usize,
    replans: u64,
    /// Bumped by every event that changes the union frontier (see the
    /// module docs' incremental section).
    frontier_epoch: u64,
    /// Epoch at which the last replan actually ran (`u64::MAX` =
    /// never), the no-change fast-exit key.
    last_replan_epoch: u64,
    /// Cumulative replanning effort; never reset (unlike the
    /// [`Self::replans`] cadence counter, which resets on idle).
    stats: ReplanStats,
}

impl GraphPartition {
    pub fn new(config: GpConfig) -> GraphPartition {
        GraphPartition {
            config,
            jobs: Vec::new(),
            current: 0,
            last_result: None,
            ratios: Vec::new(),
            workspace: PartitionWorkspace::new(),
            dev_free_ms: Vec::new(),
            finishes_since_replan: 0,
            replans: 0,
            frontier_epoch: 0,
            last_replan_epoch: u64::MAX,
            stats: ReplanStats::default(),
        }
    }

    /// The pinned device per node of the most recently submitted job
    /// (valid after a plan is installed).
    pub fn parts(&self) -> &[DeviceId] {
        self.jobs.get(self.current).map(|j| j.parts.as_slice()).unwrap_or(&[])
    }

    /// Pin table of one specific job (empty if never submitted).
    pub fn job_parts(&self, job: JobId) -> &[DeviceId] {
        self.jobs.get(job).map(|j| j.parts.as_slice()).unwrap_or(&[])
    }

    /// Partition quality of the last (re)plan.
    pub fn last_result(&self) -> Option<&PartitionResult> {
        self.last_result.as_ref()
    }

    /// Workload ratios used for the last (re)plan (Formula 1/2).
    pub fn ratios(&self) -> &[f64] {
        &self.ratios
    }

    /// Number of windowed replans performed since the system last went
    /// idle (the counter survives admissions that interleave with
    /// in-flight completions).
    pub fn replans(&self) -> u64 {
        self.replans
    }

    /// Build a plan and install it (as job 0) in one step — the
    /// offline-tool path (`hetsched partition`, examples, tests).
    /// Engines instead pair [`Planner::build_plan`] (or a cache hit)
    /// with [`Scheduler::on_submit`].
    pub fn plan_now(&mut self, dag: &Dag, platform: &Platform, model: &dyn PerfModel) -> Arc<Plan> {
        let plan = Arc::new(self.build_plan(dag, platform, model));
        self.on_submit(0, dag, &plan, platform, model);
        plan
    }

    /// Aggregate workload ratios over a whole (possibly heterogeneous)
    /// DAG: `R_d ∝ 1 / T_d` where `T_d` is the total time of running
    /// *every* kernel on device `d`. For the paper's homogeneous tasks
    /// this is exactly Formula (1)/(2).
    pub fn aggregate_ratios(dag: &Dag, platform: &Platform, model: &dyn PerfModel) -> Vec<f64> {
        let k = platform.device_count();
        let mut totals = vec![0.0f64; k];
        for (_, node) in dag.nodes() {
            if node.kernel == KernelKind::Source {
                continue;
            }
            for (d, t) in totals.iter_mut().enumerate() {
                *t += model.kernel_time_ms(node.kernel, node.size, d);
            }
        }
        ratios_from_totals(&totals)
    }

    /// Host-anchor edge weight per node: the transfer time of initial
    /// inputs not fed by an in-edge plus the result write-back for sinks
    /// (0 = no anchor edge).
    fn anchor_weights(dag: &Dag, model: &dyn PerfModel) -> Vec<i64> {
        let mut anchor_w = vec![0i64; dag.node_count()];
        for (id, node) in dag.nodes() {
            if node.kernel == KernelKind::Source {
                continue;
            }
            let mat_bytes = 4 * node.size as u64 * node.size as u64;
            let mut w = 0i64;
            // Initial inputs not fed by an in-edge.
            let missing = node.kernel.arity().saturating_sub(dag.in_degree(id));
            w += missing as i64 * edge_weight_us(model, mat_bytes);
            // Result write-back for sinks.
            if dag.out_degree(id) == 0 {
                w += edge_weight_us(model, mat_bytes);
            }
            anchor_w[id] = w;
        }
        anchor_w
    }

    /// The weighted METIS graph of the plan: DAG nodes/edges plus the
    /// paper's zero-weight "empty kernel" host anchor as vertex `n`.
    ///
    /// All initial data lives on host memory, and results return there;
    /// modelling both as edges to a vertex *pinned to the host partition*
    /// lets the cut metric see initial-load and write-back transfers, not
    /// just inter-kernel ones.
    fn build_graph(&self, dag: &Dag, platform: &Platform, model: &dyn PerfModel) -> CsrBuilder {
        let policy = self.config.node_weight;
        let mut builder = dag_to_builder(
            dag,
            |id: NodeId| {
                let node = dag.node(id);
                node_weight_us(model, node.kernel, node.size, platform, policy)
            },
            |eid| edge_weight_us(model, dag.edge(eid).bytes),
        );
        let anchor = builder.add_vertex(0);
        for (id, &w) in Self::anchor_weights(dag, model).iter().enumerate() {
            if w > 0 {
                builder.add_edge(anchor, id, w);
            }
        }
        builder
    }

    /// Partition `builder`'s graph with `fixed` pins and `ratios`
    /// targets, updating the inspection state; returns the result.
    /// With `warm` the previous assignment (plus [`WARM_FREE`] holes)
    /// seeds a single direct boundary refinement pass (incremental
    /// replans); without it the full multilevel pipeline runs
    /// (initial plans, reference replans).
    fn run_partition(
        &mut self,
        builder: CsrBuilder,
        k: usize,
        fixed: Vec<i32>,
        ratios: Vec<f64>,
        warm: Option<&[usize]>,
    ) -> PartitionResult {
        let metis = builder.build();
        let cfg = PartitionConfig {
            k,
            targets: Some(ratios.clone()),
            epsilon: self.config.epsilon,
            seed: self.config.seed,
            fixed: Some(fixed),
            ..Default::default()
        };
        let result = match warm {
            Some(w) => partition_warm_with(&metis, &cfg, w, &mut self.workspace),
            None => partition_with(&metis, &cfg, &mut self.workspace),
        };
        self.ratios = ratios;
        self.last_result = Some(result.clone());
        result
    }

    /// Windowed replan: re-partition the undispatched **union frontier**
    /// of every in-flight job — their vertices concatenated in job-id
    /// order plus one shared host anchor — with dispatched tasks pinned
    /// to their devices and ratios recomputed over the union's remaining
    /// kernels. With a single in-flight job this is exactly the per-job
    /// frontier replan.
    ///
    /// Balance semantics (deliberate): the ratio vector equalizes
    /// *projected completion* — remaining-work speeds corrected by the
    /// per-device backlog snapshot (see the struct's `dev_free_ms`) —
    /// and each part's balance target spans the *total* snapshot
    /// weight, with pinned (dispatched) weight counting toward its
    /// part. A device that the aggregate plans starved therefore
    /// receives more than its proportional share of the frontier —
    /// mirror-measured to beat both one-shot gp and the
    /// remaining-weight-only alternative (which re-creates Formula
    /// (1)'s blindness to device backlog) on the phased workload.
    fn replan_frontier(&mut self) {
        // No-change fast exit (incremental mode): the frontier epoch is
        // bumped by every event that can alter the merged graph or its
        // pins, so an unchanged epoch means this replan would reproduce
        // the previous (deterministic) result verbatim.
        if self.config.incremental && self.last_replan_epoch == self.frontier_epoch {
            self.stats.skipped += 1;
            return;
        }
        let t0 = std::time::Instant::now();
        let active: Vec<usize> =
            (0..self.jobs.len()).filter(|&j| self.jobs[j].active).collect();
        let Some(&first) = active.first() else { return };
        let k = self.jobs[first].frontier.k;

        // Union remaining-work ratios.
        let mut totals = vec![0.0f64; k];
        let mut remaining = 0usize;
        for &j in &active {
            let s = &self.jobs[j];
            let f = &s.frontier;
            for v in 0..f.node_w.len() {
                if !f.real[v] || s.dispatched[v] {
                    continue;
                }
                remaining += 1;
                for (d, t) in totals.iter_mut().enumerate() {
                    *t += f.dev_time[v * k + d];
                }
            }
        }
        if remaining == 0 {
            return;
        }
        // Backlog-aware targets: equalize *projected completion* rather
        // than raw remaining work. With `blog[d]` the device's relative
        // backlog (free-horizon above the least-loaded device; down
        // devices saturate at 1e7 ms) and `inv[d] = 1/T_d` its speed on
        // the remaining union, solving `blog[d] + ratios[d]/inv[d] = c`
        // under `Σ ratios = 1` gives every device the share that makes
        // them all finish together. A backlogged device gets *less* new
        // work, an idle one more — exactly what the remaining-work-only
        // Formula (1)/(2) ratios cannot see. Floored at 1e-3 so a
        // hopelessly behind device keeps a nonzero (renormalized) target.
        let dev_free: &[f64] =
            if self.dev_free_ms.len() == k { &self.dev_free_ms } else { &[] };
        let mn = dev_free
            .iter()
            .copied()
            .filter(|f| f.is_finite())
            .fold(f64::INFINITY, f64::min);
        let mn = if mn.is_finite() { mn } else { 0.0 };
        let blog: Vec<f64> = (0..k)
            .map(|d| {
                let f = dev_free.get(d).copied().unwrap_or(0.0);
                if f.is_finite() {
                    (f - mn).min(1e7)
                } else {
                    1e7
                }
            })
            .collect();
        let inv: Vec<f64> = totals.iter().map(|&t| 1.0 / t.max(1e-12)).collect();
        let c = (1.0 + blog.iter().zip(&inv).map(|(b, i)| b * i).sum::<f64>())
            / inv.iter().sum::<f64>();
        let mut ratios: Vec<f64> =
            blog.iter().zip(&inv).map(|(b, i)| ((c - b) * i).max(1e-3)).collect();
        let rsum: f64 = ratios.iter().sum();
        for r in ratios.iter_mut() {
            *r /= rsum;
        }

        // Merged graph: each job's vertices at its offset, one anchor.
        let total_n: usize = active.iter().map(|&j| self.jobs[j].frontier.node_w.len()).sum();
        let total_m: usize =
            active.iter().map(|&j| self.jobs[j].frontier.edges.len()).sum::<usize>() + total_n;
        let mut builder = CsrBuilder::with_capacity(total_n, total_m);
        let mut offsets = Vec::with_capacity(active.len());
        let mut base = 0usize;
        for &j in &active {
            offsets.push(base);
            for (v, &w) in self.jobs[j].frontier.node_w.iter().enumerate() {
                builder.set_vertex_weight(base + v, w);
            }
            base += self.jobs[j].frontier.node_w.len();
        }
        let anchor = builder.add_vertex(0);
        for (&j, &off) in active.iter().zip(&offsets) {
            let f = &self.jobs[j].frontier;
            for v in 0..f.node_w.len() {
                if f.anchor_w[v] > 0 {
                    builder.add_edge(anchor, off + v, f.anchor_w[v]);
                }
            }
        }
        for (&j, &off) in active.iter().zip(&offsets) {
            for &(u, v, w) in &self.jobs[j].frontier.edges {
                builder.add_edge(off + u as usize, off + v as usize, w);
            }
        }

        let mut fixed = vec![-1i32; total_n + 1];
        fixed[anchor] = 0; // host partition = device 0's memory node
        for (&j, &off) in active.iter().zip(&offsets) {
            let s = &self.jobs[j];
            for v in 0..s.dispatched.len() {
                if s.dispatched[v] {
                    fixed[off + v] = s.parts[v] as i32;
                }
            }
        }

        // Warm start (incremental mode): scatter the previous per-job
        // pin tables over the merged graph; the anchor warm-starts on
        // its pinned host part. Jobs that never went through a merged
        // replan scatter WARM_FREE instead — their solo plan ignored
        // the rest of the system, so `warm_place` seeds them against
        // the union's real balance.
        let warm = if self.config.incremental {
            let mut w = vec![0usize; total_n + 1];
            for (&j, &off) in active.iter().zip(&offsets) {
                let s = &self.jobs[j];
                for (v, &p) in s.parts.iter().enumerate() {
                    w[off + v] = if s.merged { p } else { WARM_FREE };
                }
            }
            Some(w)
        } else {
            None
        };

        let result = self.run_partition(builder, k, fixed, ratios, warm.as_deref());
        for (&j, &off) in active.iter().zip(&offsets) {
            let n = self.jobs[j].frontier.node_w.len();
            self.jobs[j].parts = result.parts[off..off + n].to_vec();
            self.jobs[j].merged = true;
        }
        self.replans += 1;
        self.last_replan_epoch = self.frontier_epoch;
        self.stats.replans += 1;
        self.stats.cost_ns += t0.elapsed().as_nanos() as u64;
    }
}

/// `R_d ∝ 1 / T_d`, normalized.
fn ratios_from_totals(totals: &[f64]) -> Vec<f64> {
    let inv: Vec<f64> = totals.iter().map(|&t| 1.0 / t.max(1e-12)).collect();
    let sum: f64 = inv.iter().sum();
    inv.iter().map(|i| i / sum).collect()
}

impl Planner for GraphPartition {
    fn build_plan(&mut self, dag: &Dag, platform: &Platform, model: &dyn PerfModel) -> Plan {
        let t0 = std::time::Instant::now();
        let n = dag.node_count();
        let k = platform.device_count();
        let builder = self.build_graph(dag, platform, model);
        let mut fixed = vec![-1i32; n + 1];
        fixed[n] = 0; // host anchor
        let ratios = Self::aggregate_ratios(dag, platform, model);
        let result = self.run_partition(builder, k, fixed, ratios, None);
        Plan {
            policy: self.name(),
            pins: result.parts[..n].to_vec(),
            ratios: self.ratios.clone(),
            quality: self.last_result.clone(),
            cost_ns: t0.elapsed().as_nanos() as u64,
        }
    }
}

impl Scheduler for GraphPartition {
    fn name(&self) -> &'static str {
        if self.config.window.is_some() {
            "gp-window"
        } else {
            "gp"
        }
    }

    fn fingerprint(&self) -> u64 {
        let mut h = plan::fnv1a(self.name().as_bytes());
        h ^= self.config.epsilon.to_bits().rotate_left(1);
        h = h.wrapping_mul(0x100000001b3).wrapping_add(self.config.seed);
        h = h.wrapping_mul(0x100000001b3).wrapping_add(match self.config.node_weight {
            NodeWeightPolicy::GpuTime => 1,
            NodeWeightPolicy::CpuTime => 2,
            NodeWeightPolicy::MeanTime => 3,
        });
        h = h
            .wrapping_mul(0x100000001b3)
            .wrapping_add(self.config.window.map(|w| w as u64 + 1).unwrap_or(0));
        h.wrapping_mul(0x100000001b3).wrapping_add(self.config.incremental as u64)
    }

    fn on_submit(
        &mut self,
        job: JobId,
        dag: &Dag,
        plan: &Arc<Plan>,
        platform: &Platform,
        model: &dyn PerfModel,
    ) {
        if self.jobs.len() <= job {
            self.jobs.resize_with(job + 1, JobState::default);
        }
        self.current = job;
        self.frontier_epoch += 1; // admission changes the union frontier
        // Reset the window counter only when the system was idle: under
        // sustained arrivals an admission must not starve the replan
        // cadence of the jobs already in flight (a reset per admission
        // would silently degenerate gp:window to one-shot gp whenever
        // jobs arrive more often than every `window` completions).
        if !self.jobs.iter().any(|s| s.active) {
            self.replans = 0;
            self.finishes_since_replan = 0;
        }
        self.last_result = plan.quality.clone();
        self.ratios = plan.ratios.clone();
        let state = &mut self.jobs[job];
        state.active = true;
        state.merged = false; // solo plan until the first merged replan
        state.parts = plan.pins.clone();
        state.dispatched = vec![false; dag.node_count()];
        state.frontier = FrontierState::default();
        if self.config.window.is_none() {
            return;
        }
        // Snapshot the weighting so replans are model-free.
        let n = dag.node_count();
        let k = platform.device_count();
        let policy = self.config.node_weight;
        let anchor_w = Self::anchor_weights(dag, model);
        let mut node_w = Vec::with_capacity(n);
        let mut dev_time = Vec::with_capacity(n * k);
        let mut real = Vec::with_capacity(n);
        for (_, node) in dag.nodes() {
            node_w.push(node_weight_us(model, node.kernel, node.size, platform, policy));
            real.push(node.kernel != KernelKind::Source);
            for d in 0..k {
                dev_time.push(model.kernel_time_ms(node.kernel, node.size, d));
            }
        }
        let edges = dag
            .edges()
            .map(|(_, e)| (e.src as u32, e.dst as u32, edge_weight_us(model, e.bytes).max(1)))
            .collect();
        self.jobs[job].frontier = FrontierState { node_w, anchor_w, edges, dev_time, real, k };
    }

    fn select(&mut self, ctx: &DispatchCtx) -> DeviceId {
        // Pure table lookup: the singular offline decision, amortized.
        let state = &mut self.jobs[ctx.job];
        if self.config.window.is_some() {
            // Deadline-slack override (windowed only — the one-shot policy
            // honors the paper's immutable table): when the pin would blow
            // a finite deadline but some other device still meets it,
            // re-pin to the least-slack meeting device.
            if ctx.deadline_ms.is_finite()
                && ctx.estimated_finish_ms(state.parts[ctx.task]) > ctx.deadline_ms
            {
                if let Some(d) = super::dmda::least_slack_meeting(ctx) {
                    state.parts[ctx.task] = d;
                }
            }
            if !state.dispatched[ctx.task] {
                // First dispatch: the task leaves the replannable
                // frontier and becomes a pin.
                self.frontier_epoch += 1;
            }
            // Snapshot the engine's free-horizon estimate for the
            // backlog-aware replan targets (see `replan_frontier`).
            self.dev_free_ms.clear();
            self.dev_free_ms.extend_from_slice(ctx.device_free_ms);
            state.dispatched[ctx.task] = true;
        }
        state.parts[ctx.task]
    }

    fn on_task_finish(&mut self, _job: JobId, _task: NodeId, _dev: DeviceId, _finish_ms: f64) {
        let Some(window) = self.config.window else { return };
        self.finishes_since_replan += 1;
        if self.finishes_since_replan >= window {
            self.finishes_since_replan = 0;
            self.replan_frontier();
        }
    }

    fn on_job_drain(&mut self, job: JobId) {
        // Retire the job from the union frontier. The dispatch bitmap and
        // weight snapshot are kept: a device failure can *revoke* a drain
        // (a committed-but-unfinished task gets killed), in which case
        // `on_task_killed` re-activates the job and the frontier must
        // still describe it.
        if let Some(state) = self.jobs.get_mut(job) {
            if state.active {
                self.frontier_epoch += 1;
            }
            state.active = false;
        }
    }

    fn on_task_killed(&mut self, job: JobId, task: NodeId) {
        let Some(state) = self.jobs.get_mut(job) else { return };
        // Revoked drain: the job is back in flight.
        state.active = true;
        if self.config.window.is_some() && task < state.dispatched.len() {
            // Return the task to the union frontier; the next replan
            // re-pins it knowing the post-failure device balance.
            state.dispatched[task] = false;
        }
        self.frontier_epoch += 1;
    }

    fn on_device_down(&mut self, _dev: DeviceId) -> usize {
        if self.config.window.is_none() {
            return 0;
        }
        // Recovery replan: re-pin the whole union frontier (now including
        // the killed tasks) immediately, and restart the window cadence.
        // The epoch bump *before* replanning guarantees the incremental
        // fast exit never swallows a forced recovery replan.
        let before = self.replans;
        self.finishes_since_replan = 0;
        self.frontier_epoch += 1;
        self.replan_frontier();
        (self.replans - before) as usize
    }

    fn on_device_up(&mut self, _dev: DeviceId) -> usize {
        if self.config.window.is_none() {
            return 0;
        }
        // The recovered device is idle capacity the last plan never saw.
        let before = self.replans;
        self.finishes_since_replan = 0;
        self.frontier_epoch += 1;
        self.replan_frontier();
        (self.replans - before) as usize
    }

    fn replan_stats(&self) -> ReplanStats {
        self.stats
    }

    fn is_offline(&self) -> bool {
        // Windowed gp revises its table while jobs run.
        self.config.window.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::generator::{generate_layered, GeneratorConfig};
    use crate::perfmodel::CalibratedModel;

    fn planned(kernel: KernelKind, size: u32) -> GraphPartition {
        let dag = generate_layered(&GeneratorConfig::paper(kernel, size));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut gp = GraphPartition::new(GpConfig::default());
        gp.plan_now(&dag, &platform, &model);
        gp
    }

    #[test]
    fn mm_large_pins_everything_to_gpu() {
        // Paper §IV.C: "the workload on the CPU is almost 0, while the
        // workload on the GPU is almost 1" for large MM.
        let gp = planned(KernelKind::Mm, 2048);
        let cpu_nodes = gp.parts().iter().filter(|&&p| p == 0).count();
        assert!(cpu_nodes <= 1, "{cpu_nodes} nodes on CPU, expected ~0");
        assert!(gp.ratios()[0] < 0.02);
    }

    #[test]
    fn ma_large_splits_work() {
        // MA's small device gap leaves the CPU a real share.
        let gp = planned(KernelKind::Ma, 2048);
        let cpu_nodes = gp.parts().iter().filter(|&&p| p == 0).count();
        assert!(cpu_nodes >= 2, "CPU should receive some MA kernels, got {cpu_nodes}");
        let gpu_nodes = gp.parts().iter().filter(|&&p| p == 1).count();
        assert!(gpu_nodes > cpu_nodes, "GPU is still faster overall");
    }

    #[test]
    fn ratios_match_formula1() {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let r = GraphPartition::aggregate_ratios(&dag, &platform, &model);
        let t_cpu = model.kernel_time_ms(KernelKind::Ma, 1024, 0);
        let t_gpu = model.kernel_time_ms(KernelKind::Ma, 1024, 1);
        // Homogeneous graph: aggregate == per-kernel Formula (1).
        assert!((r[0] - t_gpu / (t_gpu + t_cpu)).abs() < 1e-9);
    }

    #[test]
    fn select_is_pinned_lookup() {
        let mut gp = planned(KernelKind::Ma, 1024);
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let parts = gp.parts().to_vec();
        // Whatever the dynamic state says, the pin wins.
        for task in 0..parts.len() {
            let free = [999.0, 0.0];
            let ctx = DispatchCtx {
                job: 0,
                task,
                kernel: KernelKind::Ma,
                size: 1024,
                ready_ms: 0.0,
                deadline_ms: f64::INFINITY,
                device_free_ms: &free,
                inputs: &[],
                platform: &platform,
                model: &model,
            };
            assert_eq!(gp.select(&ctx), parts[task]);
        }
        assert!(gp.is_offline());
    }

    #[test]
    fn node_weight_policy_changes_plan_inputs() {
        // CPU-time weights are larger; the plan object records the policy.
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 512));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut a = GraphPartition::new(GpConfig {
            node_weight: NodeWeightPolicy::GpuTime,
            ..Default::default()
        });
        let mut b = GraphPartition::new(GpConfig {
            node_weight: NodeWeightPolicy::CpuTime,
            ..Default::default()
        });
        a.plan_now(&dag, &platform, &model);
        b.plan_now(&dag, &platform, &model);
        // Both must produce complete pinnings.
        assert_eq!(a.parts().len(), dag.node_count());
        assert_eq!(b.parts().len(), dag.node_count());
    }

    #[test]
    fn tri_device_plan_covers_all_devices_for_ma() {
        let dag = generate_layered(&GeneratorConfig::scaled(200, KernelKind::Ma, 2048, 5));
        let platform = Platform::tri_device();
        let model = CalibratedModel::tri_device();
        let mut gp = GraphPartition::new(GpConfig::default());
        gp.plan_now(&dag, &platform, &model);
        let mut counts = [0usize; 3];
        for &p in gp.parts() {
            counts[p] += 1;
        }
        assert!(counts[1] > 0, "GPU empty: {counts:?}");
        // The bandwidth-bound kernel leaves meaningful work for ≥2 devices.
        assert!(counts.iter().filter(|&&c| c > 0).count() >= 2, "{counts:?}");
    }

    #[test]
    fn plan_artifact_matches_installed_state() {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut gp = GraphPartition::new(GpConfig::default());
        let plan = gp.plan_now(&dag, &platform, &model);
        assert_eq!(plan.policy, "gp");
        assert_eq!(plan.pins, gp.parts());
        assert_eq!(plan.ratios, gp.ratios());
        assert!(plan.quality.is_some());
        // Installing the same plan into a fresh instance reproduces the
        // pinning without running the partitioner.
        let mut fresh = GraphPartition::new(GpConfig::default());
        fresh.on_submit(0, &dag, &plan, &platform, &model);
        assert_eq!(fresh.parts(), gp.parts());
    }

    #[test]
    fn per_job_pins_are_independent() {
        // Two concurrently submitted jobs keep separate tables; select
        // routes through the ctx's job id.
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let a = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, 2048));
        let b = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 2048));
        let mut gp = GraphPartition::new(GpConfig::default());
        let plan_a = Arc::new(gp.build_plan(&a, &platform, &model));
        let plan_b = Arc::new(gp.build_plan(&b, &platform, &model));
        gp.on_submit(0, &a, &plan_a, &platform, &model);
        gp.on_submit(1, &b, &plan_b, &platform, &model);
        assert_eq!(gp.job_parts(0), plan_a.pins.as_slice());
        assert_eq!(gp.job_parts(1), plan_b.pins.as_slice());
        let free = [0.0, 0.0];
        for task in 0..a.node_count() {
            let ctx = DispatchCtx {
                job: 0,
                task,
                kernel: KernelKind::Mm,
                size: 2048,
                ready_ms: 0.0,
                deadline_ms: f64::INFINITY,
                device_free_ms: &free,
                inputs: &[],
                platform: &platform,
                model: &model,
            };
            assert_eq!(gp.select(&ctx), plan_a.pins[task], "job 0 must use its own table");
        }
        gp.on_job_drain(0);
        assert_eq!(gp.job_parts(0), plan_a.pins.as_slice(), "pins survive drain");
    }

    #[test]
    fn windowed_replan_fires_and_stays_consistent() {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut gp = GraphPartition::new(GpConfig { window: Some(4), ..Default::default() });
        assert_eq!(gp.name(), "gp-window");
        assert!(!gp.is_offline());
        gp.plan_now(&dag, &platform, &model);
        let free = [0.0, 0.0];
        // Dispatch half the tasks, completing them as we go.
        let n = dag.node_count();
        for task in 0..n / 2 {
            let ctx = DispatchCtx {
                job: 0,
                task,
                kernel: KernelKind::Ma,
                size: 1024,
                ready_ms: 0.0,
                deadline_ms: f64::INFINITY,
                device_free_ms: &free,
                inputs: &[],
                platform: &platform,
                model: &model,
            };
            let before = gp.parts()[task];
            let got = gp.select(&ctx);
            assert_eq!(got, before, "select must honor the current table");
            gp.on_task_finish(0, task, got, 1.0);
        }
        assert_eq!(gp.replans(), (n / 2 / 4) as u64, "one replan per window");
        // Dispatched pins survive every replan.
        for task in 0..n / 2 {
            assert!(gp.parts()[task] < platform.device_count());
        }
        assert_eq!(gp.parts().len(), n);
        gp.on_job_drain(0);
        gp.on_drain();
    }

    #[test]
    fn windowed_replan_is_deterministic() {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let run = || {
            let mut gp = GraphPartition::new(GpConfig { window: Some(3), ..Default::default() });
            gp.plan_now(&dag, &platform, &model);
            let free = [0.0, 0.0];
            for task in 0..12 {
                let ctx = DispatchCtx {
                    job: 0,
                    task,
                    kernel: KernelKind::Ma,
                    size: 1024,
                    ready_ms: 0.0,
                    deadline_ms: f64::INFINITY,
                    device_free_ms: &free,
                    inputs: &[],
                    platform: &platform,
                    model: &model,
                };
                let d = gp.select(&ctx);
                gp.on_task_finish(0, task, d, 0.0);
            }
            gp.parts().to_vec()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn union_replan_spans_in_flight_jobs() {
        // With two phased jobs in flight, a replan must re-pin both
        // jobs' frontiers (the union graph), keeping dispatched pins.
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let a = crate::dag::workloads::phased(8, 4, 256);
        let b = crate::dag::workloads::phased(8, 4, 256);
        let mut gp = GraphPartition::new(GpConfig { window: Some(6), ..Default::default() });
        let plan_a = Arc::new(gp.build_plan(&a, &platform, &model));
        let plan_b = Arc::new(gp.build_plan(&b, &platform, &model));
        gp.on_submit(0, &a, &plan_a, &platform, &model);
        gp.on_submit(1, &b, &plan_b, &platform, &model);
        let free = [0.0, 0.0];
        // Dispatch + finish 6 tasks of job 0 -> one union replan.
        for task in 0..6 {
            let ctx = DispatchCtx {
                job: 0,
                task,
                kernel: KernelKind::Mm,
                size: 256,
                ready_ms: 0.0,
                deadline_ms: f64::INFINITY,
                device_free_ms: &free,
                inputs: &[],
                platform: &platform,
                model: &model,
            };
            let d = gp.select(&ctx);
            gp.on_task_finish(0, task, d, 1.0);
        }
        assert_eq!(gp.replans(), 1, "window of 6 -> one replan");
        // Both jobs still fully pinned to valid devices.
        assert_eq!(gp.job_parts(0).len(), a.node_count());
        assert_eq!(gp.job_parts(1).len(), b.node_count());
        assert!(gp.job_parts(0).iter().all(|&p| p < 2));
        assert!(gp.job_parts(1).iter().all(|&p| p < 2));
        // Dispatched tasks of job 0 kept their pins.
        for task in 0..6 {
            assert_eq!(gp.job_parts(0)[task], plan_a.pins[task], "dispatched pin moved");
        }
    }

    #[test]
    fn kill_and_device_down_trigger_recovery_replan() {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut gp = GraphPartition::new(GpConfig { window: Some(100), ..Default::default() });
        gp.plan_now(&dag, &platform, &model);
        let free = [0.0, 0.0];
        for task in 0..4 {
            let ctx = DispatchCtx {
                job: 0,
                task,
                kernel: KernelKind::Ma,
                size: 1024,
                ready_ms: 0.0,
                deadline_ms: f64::INFINITY,
                device_free_ms: &free,
                inputs: &[],
                platform: &platform,
                model: &model,
            };
            gp.select(&ctx);
        }
        assert_eq!(gp.replans(), 0, "window of 100 never fires on its own");
        // A failure kills task 2 and forces an immediate frontier replan.
        gp.on_task_killed(0, 2);
        assert!(!gp.jobs[0].dispatched[2], "killed task re-enters the frontier");
        assert_eq!(gp.on_device_down(1), 1, "forced recovery replan");
        assert_eq!(gp.replans(), 1);
        assert_eq!(gp.parts().len(), dag.node_count(), "table stays complete");
        assert_eq!(gp.on_device_up(1), 1, "recovery replan on the way back up");
        // One-shot gp takes the no-op defaults.
        let mut oneshot = planned(KernelKind::Ma, 1024);
        oneshot.on_task_killed(0, 0);
        assert_eq!(oneshot.on_device_down(1), 0);
        assert_eq!(oneshot.on_device_up(1), 0);
    }

    #[test]
    fn incremental_replan_skips_no_change_windows() {
        // After all selects have happened, further window firings see an
        // unchanged frontier epoch: the replan is a free skip (satellite
        // of the incremental tentpole — a no-change replan costs ~0).
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut gp = GraphPartition::new(GpConfig { window: Some(4), ..Default::default() });
        gp.plan_now(&dag, &platform, &model);
        let free = [0.0, 0.0];
        for task in 0..8 {
            let ctx = DispatchCtx {
                job: 0,
                task,
                kernel: KernelKind::Ma,
                size: 1024,
                ready_ms: 0.0,
                deadline_ms: f64::INFINITY,
                device_free_ms: &free,
                inputs: &[],
                platform: &platform,
                model: &model,
            };
            gp.select(&ctx);
        }
        // First window: selects changed the epoch -> replan runs.
        for task in 0..4 {
            gp.on_task_finish(0, task, 0, 1.0);
        }
        let stats = gp.replan_stats();
        assert_eq!(stats.replans, 1);
        assert_eq!(stats.skipped, 0);
        let cost_after_first = stats.cost_ns;
        // Second window: nothing dispatched since -> epoch unchanged ->
        // skipped, with zero added cost (the plan_ns ~ 0 property).
        for task in 4..8 {
            gp.on_task_finish(0, task, 0, 1.0);
        }
        let stats = gp.replan_stats();
        assert_eq!(stats.replans, 1, "no-change window must not re-partition");
        assert_eq!(stats.skipped, 1);
        assert_eq!(stats.cost_ns, cost_after_first, "skipped replan must cost nothing");
        assert_eq!(gp.replans(), 1, "cadence counter counts real replans only");
    }

    #[test]
    fn scratch_mode_never_skips_and_stays_legal() {
        // incremental=0 is the reference arm: every window firing runs
        // the full multilevel pipeline, and both arms end with complete
        // legal pin tables.
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let run = |incremental: bool| {
            let mut gp = GraphPartition::new(GpConfig {
                window: Some(4),
                incremental,
                ..Default::default()
            });
            gp.plan_now(&dag, &platform, &model);
            let free = [0.0, 0.0];
            for task in 0..8 {
                let ctx = DispatchCtx {
                    job: 0,
                    task,
                    kernel: KernelKind::Ma,
                    size: 1024,
                    ready_ms: 0.0,
                    deadline_ms: f64::INFINITY,
                    device_free_ms: &free,
                    inputs: &[],
                    platform: &platform,
                    model: &model,
                };
                gp.select(&ctx);
                gp.on_task_finish(0, task, 0, 1.0);
            }
            // Two more no-change windows.
            for task in 0..8 {
                gp.on_task_finish(0, task, 0, 2.0);
            }
            gp
        };
        let inc = run(true);
        let scr = run(false);
        assert_eq!(scr.replan_stats().skipped, 0, "scratch mode must not skip");
        assert_eq!(scr.replan_stats().replans, 4);
        assert_eq!(inc.replan_stats().replans + inc.replan_stats().skipped, 4);
        assert!(inc.replan_stats().skipped >= 2, "no-change windows must skip");
        for gp in [&inc, &scr] {
            assert_eq!(gp.parts().len(), dag.node_count());
            assert!(gp.parts().iter().all(|&p| p < platform.device_count()));
        }
    }

    #[test]
    fn fingerprint_distinguishes_incremental_mode() {
        let a = GraphPartition::new(GpConfig { window: Some(4), ..Default::default() });
        let b = GraphPartition::new(GpConfig {
            window: Some(4),
            incremental: false,
            ..Default::default()
        });
        assert_ne!(a.fingerprint(), b.fingerprint(), "PlanCache would mix the two arms");
    }

    #[test]
    fn drain_revocation_reactivates_job() {
        // on_job_drain keeps the frontier snapshot so a revoked drain
        // (kill after the last task committed) can resume replanning.
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut gp = GraphPartition::new(GpConfig { window: Some(100), ..Default::default() });
        gp.plan_now(&dag, &platform, &model);
        gp.on_job_drain(0);
        assert!(!gp.jobs[0].active);
        assert!(!gp.jobs[0].dispatched.is_empty(), "bitmap survives drain");
        gp.on_task_killed(0, 1);
        assert!(gp.jobs[0].active, "revoked drain re-activates the job");
        assert_eq!(gp.on_device_down(1), 1, "re-activated job is replannable");
    }
}
