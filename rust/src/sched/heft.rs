//! HEFT-style policy (Topcuoglu et al.): a classic heterogeneous list
//! scheduler, included as a stronger literature baseline than the paper's
//! set.
//!
//! Full HEFT orders tasks by upward rank and assigns each to the
//! earliest-finish-time processor. Our engines dispatch in dependency-
//! readiness order, so the rank is used as a tiebreak/insertion hint and
//! the device choice is the EFT rule — the part of HEFT that matters for
//! device selection. The upward ranks are per-job *online* state — they
//! inform no pinned decision — so they are recomputed in `on_submit`
//! with mean execution and mean transfer costs, per the original
//! formulation, and the plan artifact stays trivial.

use std::sync::Arc;

use super::{DispatchCtx, JobId, Plan, Planner, Scheduler};
use crate::dag::{topo, Dag};
use crate::perfmodel::PerfModel;
use crate::platform::{DeviceId, Platform};

/// Earliest-finish-time selection with precomputed upward ranks.
#[derive(Debug, Default)]
pub struct Heft {
    /// Upward rank per node of the current job (exposed for
    /// tests/analysis).
    ranks: Vec<f64>,
}

impl Heft {
    pub fn new() -> Heft {
        Heft::default()
    }

    pub fn ranks(&self) -> &[f64] {
        &self.ranks
    }

    /// Recompute the upward ranks for `dag`:
    /// `rank_u(v) = mean_exec(v) + max over succs (mean_comm + rank_u)`.
    pub fn compute_ranks(&mut self, dag: &Dag, platform: &Platform, model: &dyn PerfModel) {
        let k = platform.device_count();
        let mean_exec = |id: usize| -> f64 {
            let n = dag.node(id);
            (0..k).map(|d| model.kernel_time_ms(n.kernel, n.size, d)).sum::<f64>() / k as f64
        };
        let order = topo::topo_order(dag).expect("HEFT requires a DAG");
        let mut ranks = vec![0.0f64; dag.node_count()];
        for &u in order.iter().rev() {
            let mut best = 0.0f64;
            for &e in dag.out_edges(u) {
                let edge = dag.edge(e);
                // Mean communication: transfer happens with probability
                // (k-1)/k when endpoints land on different devices.
                let comm = model.transfer_time_ms(edge.bytes) * (k as f64 - 1.0) / k as f64;
                best = best.max(comm + ranks[edge.dst]);
            }
            ranks[u] = mean_exec(u) + best;
        }
        self.ranks = ranks;
    }
}

impl Planner for Heft {
    /// Online policy: the ranks are per-job state, not a plan.
    fn build_plan(&mut self, _dag: &Dag, _platform: &Platform, _model: &dyn PerfModel) -> Plan {
        Plan::trivial("heft")
    }
}

impl Scheduler for Heft {
    fn name(&self) -> &'static str {
        "heft"
    }

    fn on_submit(
        &mut self,
        _job: JobId,
        dag: &Dag,
        _plan: &Arc<Plan>,
        platform: &Platform,
        model: &dyn PerfModel,
    ) {
        // Ranks of the most recently admitted job. `select` uses only
        // the EFT estimator (rank is an ordering hint our
        // readiness-ordered engines already provide), so concurrent jobs
        // sharing this buffer cannot change any decision.
        self.compute_ranks(dag, platform, model);
    }

    fn select(&mut self, ctx: &DispatchCtx) -> DeviceId {
        // EFT rule — identical objective to dmda's estimator; strict `<`
        // keeps ties on the lowest device id.
        let mut best = 0usize;
        let mut best_t = f64::INFINITY;
        for d in 0..ctx.device_free_ms.len() {
            let t = ctx.estimated_finish_ms(d);
            if t < best_t {
                best_t = t;
                best = d;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{generator::{generate_layered, GeneratorConfig}, KernelKind};
    use crate::perfmodel::CalibratedModel;

    #[test]
    fn ranks_decrease_toward_sinks() {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, 512));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut h = Heft::new();
        h.compute_ranks(&dag, &platform, &model);
        for (_, e) in dag.edges() {
            assert!(
                h.ranks()[e.src] > h.ranks()[e.dst],
                "rank must strictly decrease along edges"
            );
        }
    }

    #[test]
    fn sinks_rank_equals_mean_exec() {
        let dag = crate::dag::workloads::chain(3, KernelKind::Ma, 256);
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut h = Heft::new();
        h.compute_ranks(&dag, &platform, &model);
        let sink = 2;
        let mean = (model.kernel_time_ms(KernelKind::Ma, 256, 0)
            + model.kernel_time_ms(KernelKind::Ma, 256, 1))
            / 2.0;
        assert!((h.ranks()[sink] - mean).abs() < 1e-9);
    }

    #[test]
    fn selects_like_eft() {
        let dag = crate::dag::workloads::chain(2, KernelKind::Mm, 1024);
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut h = Heft::new();
        h.compute_ranks(&dag, &platform, &model);
        let free = [0.0, 0.0];
        let ctx = DispatchCtx {
            job: 0,
            task: 0,
            kernel: KernelKind::Mm,
            size: 1024,
            ready_ms: 0.0,
            deadline_ms: f64::INFINITY,
            device_free_ms: &free,
            inputs: &[],
            platform: &platform,
            model: &model,
        };
        assert_eq!(h.select(&ctx), 1, "big MM -> GPU under EFT");
    }
}
