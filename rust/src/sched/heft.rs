//! HEFT-style policy (Topcuoglu et al.): a classic heterogeneous list
//! scheduler, included as a stronger literature baseline than the paper's
//! set.
//!
//! Full HEFT orders tasks by upward rank and assigns each to the
//! earliest-finish-time processor. Our engines dispatch in dependency-
//! readiness order, so the rank is used as a tiebreak/insertion hint and
//! the device choice is the EFT rule — the part of HEFT that matters for
//! device selection. The upward ranks are computed in `plan` with mean
//! execution and mean transfer costs, per the original formulation.

use super::{DispatchCtx, Scheduler};
use crate::dag::{topo, Dag};
use crate::perfmodel::PerfModel;
use crate::platform::{DeviceId, Platform};

/// Earliest-finish-time selection with precomputed upward ranks.
#[derive(Debug, Default)]
pub struct Heft {
    /// Upward rank per node (exposed for tests/analysis).
    ranks: Vec<f64>,
}

impl Heft {
    pub fn new() -> Heft {
        Heft::default()
    }

    pub fn ranks(&self) -> &[f64] {
        &self.ranks
    }
}

impl Scheduler for Heft {
    fn name(&self) -> &'static str {
        "heft"
    }

    fn plan(&mut self, dag: &Dag, platform: &Platform, model: &dyn PerfModel) {
        let k = platform.device_count();
        let mean_exec = |id: usize| -> f64 {
            let n = dag.node(id);
            (0..k).map(|d| model.kernel_time_ms(n.kernel, n.size, d)).sum::<f64>() / k as f64
        };
        // rank_u(v) = mean_exec(v) + max over succs (mean_comm + rank_u).
        let order = topo::topo_order(dag).expect("HEFT requires a DAG");
        let mut ranks = vec![0.0f64; dag.node_count()];
        for &u in order.iter().rev() {
            let mut best = 0.0f64;
            for &e in dag.out_edges(u) {
                let edge = dag.edge(e);
                // Mean communication: transfer happens with probability
                // (k-1)/k when endpoints land on different devices.
                let comm = model.transfer_time_ms(edge.bytes) * (k as f64 - 1.0) / k as f64;
                best = best.max(comm + ranks[edge.dst]);
            }
            ranks[u] = mean_exec(u) + best;
        }
        self.ranks = ranks;
    }

    fn select(&mut self, ctx: &DispatchCtx) -> DeviceId {
        // EFT rule — identical objective to dmda's estimator.
        let mut best = 0usize;
        let mut best_t = f64::INFINITY;
        for d in 0..ctx.device_free_ms.len() {
            let t = ctx.estimated_finish_ms(d);
            if t < best_t {
                best_t = t;
                best = d;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{generator::{generate_layered, GeneratorConfig}, KernelKind};
    use crate::perfmodel::CalibratedModel;

    #[test]
    fn ranks_decrease_toward_sinks() {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, 512));
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut h = Heft::new();
        h.plan(&dag, &platform, &model);
        for (_, e) in dag.edges() {
            assert!(
                h.ranks()[e.src] > h.ranks()[e.dst],
                "rank must strictly decrease along edges"
            );
        }
    }

    #[test]
    fn sinks_rank_equals_mean_exec() {
        let dag = crate::dag::workloads::chain(3, KernelKind::Ma, 256);
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut h = Heft::new();
        h.plan(&dag, &platform, &model);
        let sink = 2;
        let mean = (model.kernel_time_ms(KernelKind::Ma, 256, 0)
            + model.kernel_time_ms(KernelKind::Ma, 256, 1))
            / 2.0;
        assert!((h.ranks()[sink] - mean).abs() < 1e-9);
    }

    #[test]
    fn selects_like_eft() {
        let dag = crate::dag::workloads::chain(2, KernelKind::Mm, 1024);
        let platform = Platform::paper();
        let model = CalibratedModel::default();
        let mut h = Heft::new();
        h.plan(&dag, &platform, &model);
        let free = [0.0, 0.0];
        let ctx = DispatchCtx {
            task: 0,
            kernel: KernelKind::Mm,
            size: 1024,
            ready_ms: 0.0,
            device_free_ms: &free,
            inputs: &[],
            platform: &platform,
            model: &model,
        };
        assert_eq!(h.select(&ctx), 1, "big MM -> GPU under EFT");
    }
}
