//! "Table 2" — data-transfer frequency per scheduler (paper §IV.C text,
//! tabulated): for MA tasks the eager policy incurs the most transfers,
//! dmda fewer (data-aware), graph-partition the fewest (minimal edge
//! cut); for large MM all reasonable policies converge to the all-GPU
//! transfer floor while eager thrashes data both ways.

use hetsched::benchkit::{preamble, PAPER_SIZES};
use hetsched::dag::{generate_layered, GeneratorConfig, KernelKind};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::Table;
use hetsched::sched;
use hetsched::sim::{simulate, SimConfig};

const POLICIES: [&str; 5] = ["eager", "dmda", "gp", "gpu-only", "random"];

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("table2_transfer_counts — transfer frequency per policy", &platform);

    let mut agg = [0u64; 3]; // eager, dmda, gp totals over the MA sweep
    for (kernel, label) in [(KernelKind::Ma, "MA"), (KernelKind::Mm, "MM")] {
        let mut table = Table::new(
            format!("Transfer counts, {label} kernels (38-kernel task)"),
            &["size", "eager", "dmda", "gp", "gpu-only", "random"],
        );
        let mut bytes_table = Table::new(
            format!("Transfer megabytes, {label} kernels"),
            &["size", "eager", "dmda", "gp", "gpu-only", "random"],
        );
        for &n in &PAPER_SIZES {
            let dag = generate_layered(&GeneratorConfig::paper(kernel, n));
            let mut counts = Vec::new();
            let mut mbs = Vec::new();
            for name in POLICIES {
                let mut s = sched::by_name(name).unwrap();
                let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
                counts.push(r.ledger.count);
                mbs.push(format!("{:.2}", r.ledger.bytes as f64 / 1e6));
            }
            if kernel == KernelKind::Ma && n >= 256 {
                // The robust paper claim: gp yields (near-)minimal
                // transfers at every size, and strictly minimal summed
                // over the sweep (asserted below). Below 256 the GPU is
                // not worth using at all (Fig 3 ratio < 1): dmda
                // degenerates to cpu-only with ~no transfers, which is
                // outside the claim's regime.
                if n >= 512 {
                    let best_online = counts[0].min(counts[1]);
                    assert!(counts[2] <= best_online + 2,
                        "gp must be near-minimal at {n}: {counts:?}");
                }
                agg[0] += counts[0];
                agg[1] += counts[1];
                agg[2] += counts[2];
            }
            let mut row = vec![n.to_string()];
            row.extend(counts.iter().map(u64::to_string));
            table.row(row);
            let mut row = vec![n.to_string()];
            row.extend(mbs);
            bytes_table.row(row);
        }
        println!("{}", table.render());
        println!("{}", bytes_table.render());
        let _ = table.save_csv(&format!("table2_transfers_{}", label.to_lowercase()));
    }
    assert!(agg[2] < agg[0] && agg[2] < agg[1],
        "gp must be minimal over the MA sweep (n>=256): eager={} dmda={} gp={}",
        agg[0], agg[1], agg[2]);
    println!(
        "MA sweep totals (n>=256): eager={} dmda={} gp={} — gp minimal — OK",
        agg[0], agg[1], agg[2]
    );
}
