//! Ablation D1 — the node-weight policy choice the paper discusses in
//! §III: node weights can come from GPU kernel times (smaller values →
//! edge weights get *higher* relative priority → the partitioner works
//! harder to avoid transfers) or CPU kernel times (the opposite).
//! "How this policy influences the partition results depends on graph
//! partition algorithms" — this bench measures it on ours.

use hetsched::benchkit::preamble;
use hetsched::dag::{generate_layered, GeneratorConfig, KernelKind};
use hetsched::perfmodel::{CalibratedModel, NodeWeightPolicy};
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, Table};
use hetsched::sched::{GpConfig, GraphPartition, Scheduler as _};
use hetsched::sim::{simulate, SimConfig};

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("ablation_node_weight — §III node-weight policy choice", &platform);

    let mut table = Table::new(
        "gp partitions under different node-weight policies (MA kernels)",
        &["size", "policy", "edge_cut_us", "cpu_tasks", "transfers", "makespan_ms"],
    );
    for &n in &[512u32, 1024, 2048] {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, n));
        for (policy, label) in [
            (NodeWeightPolicy::GpuTime, "gpu-time"),
            (NodeWeightPolicy::CpuTime, "cpu-time"),
            (NodeWeightPolicy::MeanTime, "mean-time"),
        ] {
            let mut gp = GraphPartition::new(GpConfig { node_weight: policy, ..Default::default() });
            let r = simulate(&dag, &mut gp, &platform, &model, &SimConfig::default());
            let cut = gp.last_result().map(|p| p.edge_cut).unwrap_or(0);
            let cpu_tasks = r.tasks_per_device[0];
            table.row(vec![
                n.to_string(),
                label.to_string(),
                cut.to_string(),
                cpu_tasks.to_string(),
                r.ledger.count.to_string(),
                fmt_ms(r.makespan_ms),
            ]);
        }
    }
    println!("{}", table.render());
    let _ = table.save_csv("ablation_node_weight");
    println!("note: smaller node weights (gpu-time) give edge weights higher");
    println!("priority during partitioning, per the paper's §III discussion.");
}
