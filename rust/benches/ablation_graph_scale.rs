//! Ablation D2 — the paper's §IV.C prediction: for MM tasks, "it can be
//! predicted that the CPU could receive a certain amount of workload only
//! when the task largely increases the number of kernels". This bench
//! sweeps the DAG size at a fixed kernel size and reports where the
//! graph-partition policy starts assigning kernels to the CPU.

use hetsched::benchkit::preamble;
use hetsched::dag::{generate_layered, GeneratorConfig, KernelKind};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, fmt_ratio, Table};
use hetsched::sched::{GpConfig, GraphPartition, Scheduler as _};
use hetsched::sim::{simulate, SimConfig};

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("ablation_graph_scale — when does the CPU earn MM work?", &platform);

    let mut table = Table::new(
        "gp CPU share vs task size (MM kernels at 2048)",
        &["kernels", "R_cpu", "cpu_tasks", "gpu_tasks", "makespan_ms", "vs_gpu_only"],
    );
    let mut first_cpu_work: Option<usize> = None;
    for &kernels in &[38usize, 76, 152, 304, 608, 1216, 2432] {
        let cfg = GeneratorConfig::scaled(kernels, KernelKind::Mm, 2048, 11);
        let dag = generate_layered(&cfg);
        let mut gp = GraphPartition::new(GpConfig::default());
        let r = simulate(&dag, &mut gp, &platform, &model, &SimConfig::default());
        let cpu_tasks = r.tasks_per_device[0];
        if cpu_tasks > 0 && first_cpu_work.is_none() {
            first_cpu_work = Some(kernels);
        }
        // Compare with everything-on-GPU.
        let mut gpu_only = hetsched::sched::PinAll::new(1);
        let g = simulate(&dag, &mut gpu_only, &platform, &model, &SimConfig::default());
        table.row(vec![
            kernels.to_string(),
            format!("{:.4}", gp.ratios()[0]),
            cpu_tasks.to_string(),
            r.tasks_per_device[1].to_string(),
            fmt_ms(r.makespan_ms),
            fmt_ratio(r.makespan_ms / g.makespan_ms),
        ]);
    }
    println!("{}", table.render());
    match first_cpu_work {
        Some(k) => println!(
            "CPU first receives MM work at {k} kernels — the paper's prediction \
             (\"only when the task largely increases the number of kernels\") holds."
        ),
        None => println!("CPU never received work in this sweep (R_cpu too small)."),
    }
    let _ = table.save_csv("ablation_graph_scale");
}
