//! P1 — partitioner substrate validation: runtime and cut quality of the
//! multilevel partitioner vs graph size (DESIGN.md §6 L3 target: ≤ 100 ms
//! for 1e5-node graphs), plus a quality sanity ratio against random
//! assignment.
//!
//! Besides the human-readable tables, the bench emits
//! `bench_results/BENCH_partitioner.json` — machine-readable rows
//! (graph size → wall ms, edge cut, cut-vs-random ratio, balance) plus
//! the workspace phase-timer breakdown — so the perf trajectory is
//! tracked across PRs.

use std::fmt::Write as _;

use hetsched::benchkit::{bench, preamble, BenchOpts};
use hetsched::dag::metis_io::MetisGraph;
use hetsched::partition::{partition_with, quality, PartitionConfig, PartitionWorkspace};
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, fmt_ratio, Table};
use hetsched::util::Pcg32;

/// Random 2D-grid-plus-chords graph (partitionable but not trivial).
/// Construction is kept identical to the seed revision so cut numbers
/// stay comparable across PRs; the nested-adjacency staging is converted
/// to CSR once, outside the timed region.
fn make_graph(n: usize, seed: u64) -> MetisGraph {
    let cols = (n as f64).sqrt().ceil() as usize;
    let mut adj: Vec<Vec<(usize, i64)>> = vec![Vec::new(); n];
    let mut rng = Pcg32::seeded(seed);
    let mut add = |a: usize, b: usize, w: i64, adj: &mut Vec<Vec<(usize, i64)>>| {
        if a != b && !adj[a].iter().any(|&(x, _)| x == b) {
            adj[a].push((b, w));
            adj[b].push((a, w));
        }
    };
    for v in 0..n {
        if v + 1 < n && (v + 1) % cols != 0 {
            add(v, v + 1, 10, &mut adj);
        }
        if v + cols < n {
            add(v, v + cols, 10, &mut adj);
        }
    }
    // 5% random chords with light weight.
    for _ in 0..n / 20 {
        let a = rng.gen_range(n as u32) as usize;
        let b = rng.gen_range(n as u32) as usize;
        add(a, b, 1, &mut adj);
    }
    MetisGraph::from_adj(vec![1; n], adj)
}

fn random_cut(g: &MetisGraph, seed: u64) -> i64 {
    let mut rng = Pcg32::seeded(seed);
    let parts: Vec<usize> = (0..g.vertex_count()).map(|_| rng.gen_range(2) as usize).collect();
    quality::edge_cut(g, &parts)
}

struct ScaleRow {
    n: usize,
    edges: usize,
    time_ms: f64,
    cut: i64,
    cut_random_ratio: f64,
    balance: f64,
}

fn main() {
    preamble("partitioner — multilevel bisection speed & quality", &Platform::paper());

    let mut ws = PartitionWorkspace::new();
    let mut phase_timer = hetsched::benchkit::PhaseTimer::new();
    let mut rows: Vec<ScaleRow> = Vec::new();
    let mut table = Table::new(
        "partitioner scaling (k=2, uniform targets)",
        &["vertices", "edges", "time_ms", "cut", "cut/random", "balance"],
    );
    for &n in &[100usize, 1_000, 10_000, 100_000] {
        let g = make_graph(n, 3);
        let cfg = PartitionConfig::default();
        let opts = BenchOpts { warmup_iters: 1, iters: if n >= 100_000 { 3 } else { 10 } };
        let summary = bench(&opts, || partition_with(&g, &cfg, &mut ws));
        ws.timer.clear();
        let res = partition_with(&g, &cfg, &mut ws);
        let rnd = random_cut(&g, 99).max(1);
        let total: i64 = res.part_weights.iter().sum();
        let balance =
            res.part_weights.iter().cloned().fold(0, i64::max) as f64 / (total as f64 / 2.0);
        let row = ScaleRow {
            n,
            edges: g.edge_count(),
            time_ms: summary.mean,
            cut: res.edge_cut,
            cut_random_ratio: res.edge_cut as f64 / rnd as f64,
            balance,
        };
        table.row(vec![
            n.to_string(),
            row.edges.to_string(),
            fmt_ms(row.time_ms),
            row.cut.to_string(),
            fmt_ratio(row.cut_random_ratio),
            fmt_ratio(row.balance),
        ]);
        assert!(
            res.edge_cut < rnd / 4,
            "multilevel cut must beat random by 4x at n={n}: {} vs {rnd}",
            res.edge_cut
        );
        if n == 100_000 {
            println!("100k-vertex partition: {:.1} ms (target <= 100 ms)", summary.mean);
            println!("phase breakdown (one run): {}", ws.timer.render());
            // Snapshot now: the timer holds exactly one 100k run here.
            phase_timer = ws.timer.clone();
        }
        rows.push(row);
    }
    println!("{}", table.render());

    // Skewed-target quality (the gp use case).
    let mut skew = Table::new(
        "skewed targets on 10k vertices (R_cpu sweep)",
        &["r0", "achieved", "cut"],
    );
    let mut skew_rows: Vec<(f64, f64, i64)> = Vec::new();
    let g = make_graph(10_000, 5);
    for &r0 in &[0.5, 0.25, 0.1, 0.05, 0.01] {
        let cfg = PartitionConfig::bipartition(r0, 1.0 - r0);
        let res = partition_with(&g, &cfg, &mut ws);
        skew.row(vec![
            fmt_ratio(r0),
            fmt_ratio(res.fractions()[0]),
            res.edge_cut.to_string(),
        ]);
        skew_rows.push((r0, res.fractions()[0], res.edge_cut));
    }
    println!("{}", skew.render());
    let _ = table.save_csv("partitioner");
    match save_json(&rows, &skew_rows, &phase_timer) {
        Ok(path) => println!("json written to {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_partitioner.json: {e}"),
    }
}

/// Write `bench_results/BENCH_partitioner.json`.
fn save_json(
    rows: &[ScaleRow],
    skew: &[(f64, f64, i64)],
    phase_timer: &hetsched::benchkit::PhaseTimer,
) -> std::io::Result<std::path::PathBuf> {
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"partitioner\",\n  \"harness\": \"cargo-bench\",\n");
    s.push_str("  \"scaling\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = writeln!(
            s,
            "    {{\"n\": {}, \"edges\": {}, \"time_ms\": {:.3}, \"cut\": {}, \
             \"cut_random_ratio\": {:.4}, \"balance\": {:.4}}}{}",
            r.n,
            r.edges,
            r.time_ms,
            r.cut,
            r.cut_random_ratio,
            r.balance,
            if i + 1 < rows.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"skew_10k\": [\n");
    for (i, &(r0, achieved, cut)) in skew.iter().enumerate() {
        let _ = writeln!(
            s,
            "    {{\"r0\": {r0}, \"achieved\": {achieved:.4}, \"cut\": {cut}}}{}",
            if i + 1 < skew.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"phase_ms_100k_single_run\": {\n");
    let entries = phase_timer.entries();
    for (i, (name, ms)) in entries.iter().enumerate() {
        let _ =
            writeln!(s, "    \"{name}\": {ms:.3}{}", if i + 1 < entries.len() { "," } else { "" });
    }
    s.push_str("  }\n}\n");

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_partitioner.json");
    std::fs::write(&path, s)?;
    Ok(path)
}
