//! P1 — partitioner substrate validation: runtime and cut quality of the
//! multilevel partitioner vs graph size (DESIGN.md §6 L3 target: ≤ 100 ms
//! for 1e5-node graphs), plus a quality sanity ratio against random
//! assignment.

use hetsched::benchkit::{bench, preamble, BenchOpts};
use hetsched::dag::metis_io::MetisGraph;
use hetsched::partition::{partition, quality, PartitionConfig};
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, fmt_ratio, Table};
use hetsched::util::Pcg32;

/// Random 2D-grid-plus-chords graph (partitionable but not trivial).
fn make_graph(n: usize, seed: u64) -> MetisGraph {
    let cols = (n as f64).sqrt().ceil() as usize;
    let mut adj: Vec<Vec<(usize, i64)>> = vec![Vec::new(); n];
    let mut rng = Pcg32::seeded(seed);
    let mut add = |a: usize, b: usize, w: i64, adj: &mut Vec<Vec<(usize, i64)>>| {
        if a != b && !adj[a].iter().any(|&(x, _)| x == b) {
            adj[a].push((b, w));
            adj[b].push((a, w));
        }
    };
    for v in 0..n {
        if v + 1 < n && (v + 1) % cols != 0 {
            add(v, v + 1, 10, &mut adj);
        }
        if v + cols < n {
            add(v, v + cols, 10, &mut adj);
        }
    }
    // 5% random chords with light weight.
    for _ in 0..n / 20 {
        let a = rng.gen_range(n as u32) as usize;
        let b = rng.gen_range(n as u32) as usize;
        add(a, b, 1, &mut adj);
    }
    MetisGraph { vwgt: vec![1; n], adj }
}

fn random_cut(g: &MetisGraph, seed: u64) -> i64 {
    let mut rng = Pcg32::seeded(seed);
    let parts: Vec<usize> = (0..g.vertex_count()).map(|_| rng.gen_range(2) as usize).collect();
    quality::edge_cut(g, &parts)
}

fn main() {
    preamble("partitioner — multilevel bisection speed & quality", &Platform::paper());

    let mut table = Table::new(
        "partitioner scaling (k=2, uniform targets)",
        &["vertices", "edges", "time_ms", "cut", "cut/random", "balance"],
    );
    for &n in &[100usize, 1_000, 10_000, 100_000] {
        let g = make_graph(n, 3);
        let cfg = PartitionConfig::default();
        let opts = BenchOpts { warmup_iters: 1, iters: if n >= 100_000 { 3 } else { 10 } };
        let summary = bench(&opts, || partition(&g, &cfg));
        let res = partition(&g, &cfg);
        let rnd = random_cut(&g, 99).max(1);
        let total: i64 = res.part_weights.iter().sum();
        let balance = res.part_weights.iter().cloned().fold(0, i64::max) as f64
            / (total as f64 / 2.0);
        table.row(vec![
            n.to_string(),
            g.edge_count().to_string(),
            fmt_ms(summary.mean),
            res.edge_cut.to_string(),
            fmt_ratio(res.edge_cut as f64 / rnd as f64),
            fmt_ratio(balance),
        ]);
        assert!(
            res.edge_cut < rnd / 4,
            "multilevel cut must beat random by 4x at n={n}: {} vs {rnd}",
            res.edge_cut
        );
        if n == 100_000 {
            println!("100k-vertex partition: {:.1} ms (target <= 100 ms)", summary.mean);
        }
    }
    println!("{}", table.render());

    // Skewed-target quality (the gp use case).
    let mut skew = Table::new(
        "skewed targets on 10k vertices (R_cpu sweep)",
        &["r0", "achieved", "cut"],
    );
    let g = make_graph(10_000, 5);
    for &r0 in &[0.5, 0.25, 0.1, 0.05, 0.01] {
        let cfg = PartitionConfig::bipartition(r0, 1.0 - r0);
        let res = partition(&g, &cfg);
        skew.row(vec![
            fmt_ratio(r0),
            fmt_ratio(res.fractions()[0]),
            res.edge_cut.to_string(),
        ]);
    }
    println!("{}", skew.render());
    let _ = table.save_csv("partitioner");
}
