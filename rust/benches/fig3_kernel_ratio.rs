//! Fig 3 — ratio of CPU execution time to GPU execution time per kernel,
//! sizes 64..2048 (paper §IV.B).
//!
//! Acceptance shape (DESIGN.md §4): the MM curve is steep and
//! monotonically increasing (≫10× by 1024); the MA curve stays low and
//! flattens; both start below 1 (launch overhead dominates tiny kernels).

use hetsched::benchkit::{preamble, PAPER_SIZES};
use hetsched::dag::KernelKind;
use hetsched::perfmodel::{CalibratedModel, PerfModel};
use hetsched::platform::Platform;
use hetsched::report::{fmt_ratio, Table};

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("fig3_kernel_ratio — CPU/GPU execution-time ratio", &platform);

    let mut table = Table::new(
        "Fig 3: ratio of CPU to GPU execution time (computation only)",
        &["size", "ma_cpu_ms", "ma_gpu_ms", "ma_ratio", "mm_cpu_ms", "mm_gpu_ms", "mm_ratio"],
    );
    let mut prev_mm = 0.0;
    for &n in &PAPER_SIZES {
        let t = |k: KernelKind, d: usize| model.kernel_time_ms(k, n, d);
        let ma_ratio = t(KernelKind::Ma, 0) / t(KernelKind::Ma, 1);
        let mm_ratio = t(KernelKind::Mm, 0) / t(KernelKind::Mm, 1);
        table.row(vec![
            n.to_string(),
            fmt_ratio(t(KernelKind::Ma, 0)),
            fmt_ratio(t(KernelKind::Ma, 1)),
            fmt_ratio(ma_ratio),
            fmt_ratio(t(KernelKind::Mm, 0)),
            fmt_ratio(t(KernelKind::Mm, 1)),
            fmt_ratio(mm_ratio),
        ]);
        // Paper shape assertions.
        assert!(mm_ratio >= prev_mm, "MM ratio must be monotone (steep curve)");
        assert!(ma_ratio < 12.0, "MA ratio must stay low");
        prev_mm = mm_ratio;
    }
    println!("{}", table.render());
    match table.save_csv("fig3_kernel_ratio") {
        Ok(p) => println!("csv: {}", p.display()),
        Err(e) => eprintln!("csv save failed: {e}"),
    }
    println!("shape check: MM steep+monotone, MA low — OK");
}
