//! D3 — scheduling overhead (paper §IV.D): "the dmda policy takes time to
//! make a decision, while the eager does not. The graph-partition
//! scheduler only makes a singular decision and uses the same decision
//! for all following tasks, which averages the scheduling overhead."
//!
//! Reported: per-task decision time (ns) for each policy and the one-off
//! plan time for offline policies, over growing task counts, so gp's
//! amortization is visible.

use hetsched::benchkit::preamble;
use hetsched::dag::{generate_layered, GeneratorConfig, KernelKind};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::Table;
use hetsched::sched;
use hetsched::sim::{simulate, SimConfig};

const POLICIES: [&str; 5] = ["eager", "dmda", "gp", "heft", "random"];

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("sched_overhead — §IV.D decision-time comparison", &platform);

    let mut table = Table::new(
        "scheduling overhead (MM kernels at 1024)",
        &["kernels", "policy", "decision_ns_per_task", "plan_us", "amortized_ns_per_task"],
    );
    for &kernels in &[38usize, 380, 3800] {
        let cfg = GeneratorConfig::scaled(kernels, KernelKind::Mm, 1024, 5);
        let dag = generate_layered(&cfg);
        for name in POLICIES {
            let mut s = sched::by_name(name).unwrap();
            // Median of 5 runs to de-noise wall timing.
            let mut decision = Vec::new();
            let mut plan = Vec::new();
            for _ in 0..5 {
                let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
                decision.push(r.decision_ns_per_task());
                plan.push(r.plan_ns);
            }
            decision.sort_by(|a, b| a.partial_cmp(b).unwrap());
            plan.sort_unstable();
            let d = decision[2];
            let p = plan[2];
            table.row(vec![
                kernels.to_string(),
                name.to_string(),
                format!("{d:.0}"),
                format!("{:.1}", p as f64 / 1e3),
                format!("{:.0}", d + p as f64 / kernels as f64),
            ]);
        }
    }
    println!("{}", table.render());
    let _ = table.save_csv("sched_overhead");
    println!("expected shape: eager cheapest per task; dmda pays per-decision;");
    println!("gp's plan cost amortizes away as the task count grows (§IV.D).");
}
