//! Fig 4 — ratio of GPU execution time to PCIe transfer time (2 inputs +
//! 1 output), sizes 64..2048 (paper §IV.B).
//!
//! Acceptance shape: MA stays below 1 everywhere ("requires the majority
//! of the transferring data"); MM decreases until 384, rises before 1792,
//! then descends slightly — the CUBLAS-size-optimization curve the paper
//! observes and our calibrated efficiency table reproduces.

use hetsched::benchkit::{preamble, PAPER_SIZES};
use hetsched::dag::KernelKind;
use hetsched::perfmodel::{CalibratedModel, PerfModel};
use hetsched::platform::Platform;
use hetsched::report::{fmt_ratio, Table};

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("fig4_transfer_ratio — GPU exec / PCIe transfer ratio", &platform);

    let mut table = Table::new(
        "Fig 4: ratio of GPU execution time to data transfer time (3 matrices)",
        &["size", "xfer_ms", "ma_gpu_ms", "ma_ratio", "mm_gpu_ms", "mm_ratio"],
    );
    let ratio = |k: KernelKind, n: u32| {
        let bytes = 4 * n as u64 * n as u64;
        model.kernel_time_ms(k, n, 1) / (3.0 * model.transfer_time_ms(bytes))
    };
    for &n in &PAPER_SIZES {
        let bytes = 4 * n as u64 * n as u64;
        let xfer = 3.0 * model.transfer_time_ms(bytes);
        table.row(vec![
            n.to_string(),
            fmt_ratio(xfer),
            fmt_ratio(model.kernel_time_ms(KernelKind::Ma, n, 1)),
            fmt_ratio(ratio(KernelKind::Ma, n)),
            fmt_ratio(model.kernel_time_ms(KernelKind::Mm, n, 1)),
            fmt_ratio(ratio(KernelKind::Mm, n)),
        ]);
        assert!(ratio(KernelKind::Ma, n) < 1.0, "MA must stay below 1 at {n}");
    }
    println!("{}", table.render());

    // The paper's exact dip-rise-descend sentence, as assertions.
    let mm = |n| ratio(KernelKind::Mm, n);
    assert!(mm(64) > mm(128) && mm(128) > mm(256) && mm(256) > mm(384),
        "MM ratio must decrease until 384");
    assert!(mm(384) < mm(512) && mm(512) < mm(1024) && mm(1024) < mm(1792),
        "MM ratio must rise before 1792");
    assert!(mm(2048) < mm(1792), "MM ratio must descend slightly after 1792");

    match table.save_csv("fig4_transfer_ratio") {
        Ok(p) => println!("csv: {}", p.display()),
        Err(e) => eprintln!("csv save failed: {e}"),
    }
    println!("shape check: MA<1 everywhere; MM dip@384 / rise@1792 / descend@2048 — OK");
}
