//! Ablation D5 — the paper's stated limitation, tested.
//!
//! §IV.D: "The graph-partition policy assumes that each kernel has the
//! same performance ratio between different types of processors. Hence,
//! we did not test the task consisting of different kernel types. …
//! Graph algorithm researchers may investigate this assumption in the
//! future."
//!
//! This bench runs that untested case: random DAGs whose kernels are a
//! MA/MM mix. gp plans with ONE aggregate workload ratio, so the more
//! the per-kernel ratios diverge (large sizes: MM wants the GPU ~150×,
//! MA only ~10×), the more gp's uniform-ratio assumption costs relative
//! to the per-task decisions of dmda.

use hetsched::benchkit::preamble;
use hetsched::dag::workloads::mixed_random;
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, fmt_ratio, Table};
use hetsched::sched;
use hetsched::sim::{simulate, SimConfig};

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("ablation_mixed_kernels — §IV.D untested mixed-ratio case", &platform);

    let mut table = Table::new(
        "mixed MA/MM task (100 kernels), gp's uniform-ratio assumption probed",
        &["size", "mm_frac", "eager", "dmda", "gp", "gp/dmda"],
    );
    let mut worst: f64 = 0.0;
    for &n in &[256u32, 512, 1024, 2048] {
        for &frac in &[0.25, 0.5, 0.75] {
            let dag = mixed_random(100, n, frac, 42);
            let mut times = Vec::new();
            for name in ["eager", "dmda", "gp"] {
                let mut s = sched::by_name(name).unwrap();
                let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
                times.push(r.makespan_ms);
            }
            let gp_over_dmda = times[2] / times[1];
            worst = worst.max(gp_over_dmda);
            table.row(vec![
                n.to_string(),
                format!("{frac}"),
                fmt_ms(times[0]),
                fmt_ms(times[1]),
                fmt_ms(times[2]),
                fmt_ratio(gp_over_dmda),
            ]);
            // gp must stay *functional* (the assumption degrades quality,
            // not correctness) and dominate eager at large sizes.
            if n >= 1024 {
                assert!(times[0] > times[2], "eager must still lose at {n}");
            }
        }
    }
    println!("{}", table.render());
    println!(
        "worst gp/dmda on mixed tasks: {:.2}x — the §IV.D assumption is a \
         measurable but bounded quality cost; per-kernel-type multi-\
         constraint partitioning (Tanaka & Tatebe) is the known remedy.",
        worst
    );
    let _ = table.save_csv("ablation_mixed_kernels");
}
