//! Ablation D4 — the paper's two deferred transfer-engine features:
//! compute/transfer overlap (§I: "overlapping task computation and data
//! transfer … can be used in the graph-partition approach as well") and
//! Tesla dual copy engines (§III: "this feature can alleviate data
//! transfer overhead. Taking advantage of this feature will be covered
//! in future work").
//!
//! Measured on the transfer-bound MA task, where both features should
//! matter, and the compute-bound MM task, where they should not.

use hetsched::benchkit::{preamble, PAPER_SIZES};
use hetsched::dag::{generate_layered, GeneratorConfig, KernelKind};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, fmt_ratio, Table};
use hetsched::sched;
use hetsched::sim::{simulate, SimConfig};

fn config(channels: usize, prefetch: bool) -> SimConfig {
    SimConfig { bus_channels: channels, prefetch, ..Default::default() }
}

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("ablation_overlap — prefetch + dual copy engines (future work)", &platform);

    for (kernel, label) in [(KernelKind::Ma, "MA"), (KernelKind::Mm, "MM")] {
        let mut table = Table::new(
            format!("{label} task makespan (ms) under gp, transfer-engine variants"),
            &["size", "baseline", "prefetch", "dual-copy", "both", "both/baseline"],
        );
        let mut improved_somewhere = false;
        for &n in &PAPER_SIZES {
            if n < 256 {
                continue;
            }
            let dag = generate_layered(&GeneratorConfig::paper(kernel, n));
            let mut cells = vec![n.to_string()];
            let mut base = 0.0;
            let mut both = 0.0;
            for (channels, prefetch) in [(1, false), (1, true), (2, false), (2, true)] {
                let mut s = sched::by_name("gp").unwrap();
                let r = simulate(&dag, s.as_mut(), &platform, &model, &config(channels, prefetch));
                if (channels, prefetch) == (1, false) {
                    base = r.makespan_ms;
                }
                if (channels, prefetch) == (2, true) {
                    both = r.makespan_ms;
                }
                cells.push(fmt_ms(r.makespan_ms));
            }
            cells.push(fmt_ratio(both / base));
            table.row(cells);
            assert!(
                both <= base + 1e-9,
                "{label}@{n}: overlap must never hurt ({both} vs {base})"
            );
            if both < 0.97 * base {
                improved_somewhere = true;
            }
        }
        println!("{}", table.render());
        if kernel == KernelKind::Ma {
            assert!(
                improved_somewhere,
                "transfer-bound MA must benefit from overlap somewhere"
            );
        }
        let _ = table.save_csv(&format!("ablation_overlap_{}", label.to_lowercase()));
    }
    println!("shape check: overlap helps the transfer-bound task, never hurts — OK");
}
