//! Fig 6 — execution time of the 38-kernel / 75-edge task with **matrix
//! multiplication** kernels under eager / dmda / graph-partition (§IV.C).
//!
//! Acceptance shape: eager shows the highest execution time everywhere
//! and diverges as size grows (it keeps feeding the slow CPU); dmda and
//! gp coincide at large sizes because Formula (1) drives R_cpu → 0 and gp
//! pins the whole graph to the GPU — the paper's "leaving the
//! low-efficiency processor idle can be a better option than using it".

use hetsched::benchkit::{preamble, PAPER_ITERATIONS, PAPER_SIZES};
use hetsched::dag::{generate_layered, GeneratorConfig, KernelKind};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, fmt_ratio, Table};
use hetsched::sched;
use hetsched::sched::{GpConfig, GraphPartition};
use hetsched::sim::{simulate, SimConfig};

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("fig6_mm_schedulers — task makespan, MM kernels", &platform);

    let mut table = Table::new(
        format!("Fig 6: execution time (ms), MM kernels, {PAPER_ITERATIONS} iterations"),
        &["size", "eager", "dmda", "gp", "eager/gp", "gp_cpu_tasks"],
    );
    let cfg = SimConfig::default();
    for &n in &PAPER_SIZES {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, n));
        let mut makespans = Vec::new();
        let mut gp_cpu_tasks = 0usize;
        for name in ["eager", "dmda", "gp"] {
            let mut s = sched::by_name(name).unwrap();
            let mut last = None;
            for _ in 0..PAPER_ITERATIONS {
                last = Some(simulate(&dag, s.as_mut(), &platform, &model, &cfg));
            }
            let r = last.unwrap();
            if name == "gp" {
                gp_cpu_tasks = r.tasks_per_device[0];
            }
            makespans.push(r.makespan_ms);
        }
        table.row(vec![
            n.to_string(),
            fmt_ms(makespans[0]),
            fmt_ms(makespans[1]),
            fmt_ms(makespans[2]),
            fmt_ratio(makespans[0] / makespans[2]),
            gp_cpu_tasks.to_string(),
        ]);
        if n >= 384 {
            assert!(
                makespans[0] > 2.0 * makespans[2],
                "eager must lose clearly at {n}: {makespans:?}"
            );
            assert!(
                (makespans[1] - makespans[2]).abs() / makespans[2] < 0.15,
                "dmda and gp must coincide at {n}: {makespans:?}"
            );
            assert!(gp_cpu_tasks <= 1, "gp must pin (almost) everything to GPU at {n}");
        }
    }
    println!("{}", table.render());

    // Paper's Formula (1) observation, printed for the record.
    let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, 2048));
    let mut gp = GraphPartition::new(GpConfig::default());
    gp.plan_now(&dag, &platform, &model);
    println!(
        "Formula (1) at size 2048: R_cpu={:.4} R_gpu={:.4} (paper: \"workload on the CPU is almost 0\")",
        gp.ratios()[0],
        gp.ratios()[1]
    );

    match table.save_csv("fig6_mm_schedulers") {
        Ok(p) => println!("csv: {}", p.display()),
        Err(e) => eprintln!("csv save failed: {e}"),
    }
    println!("shape check: eager diverges; dmda == gp; gp all-GPU — OK");
}
