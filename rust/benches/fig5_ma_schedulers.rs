//! Fig 5 — execution time of the 38-kernel / 75-edge task with **matrix
//! addition** kernels under eager / dmda / graph-partition (paper §IV.C).
//!
//! Protocol: the paper's 100 iterations per test case (the simulator is
//! deterministic, so the mean equals every sample; the harness still runs
//! the full count to time the engine itself). Acceptance shape: the three
//! policies stay within ~2x of each other at every size (paper: "the
//! performance is close amongst the three scheduling policies"), while
//! transfers(eager) > transfers(dmda) >= transfers(gp).

use hetsched::benchkit::{preamble, PAPER_ITERATIONS, PAPER_SIZES};
use hetsched::dag::{generate_layered, GeneratorConfig, KernelKind};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, Table};
use hetsched::sched;
use hetsched::sim::{simulate, SimConfig};
use std::time::Instant;

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    preamble("fig5_ma_schedulers — task makespan, MA kernels", &platform);

    let mut table = Table::new(
        format!("Fig 5: execution time (ms), MA kernels, {PAPER_ITERATIONS} iterations"),
        &["size", "eager", "dmda", "gp", "xfer_eager", "xfer_dmda", "xfer_gp"],
    );
    let cfg = SimConfig::default();
    let wall0 = Instant::now();
    let mut events = 0usize;
    for &n in &PAPER_SIZES {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, n));
        let mut makespans = Vec::new();
        let mut transfers = Vec::new();
        for name in ["eager", "dmda", "gp"] {
            let mut s = sched::by_name(name).unwrap();
            let mut last = None;
            for _ in 0..PAPER_ITERATIONS {
                last = Some(simulate(&dag, s.as_mut(), &platform, &model, &cfg));
                events += dag.node_count();
            }
            let r = last.unwrap();
            makespans.push(r.makespan_ms);
            transfers.push(r.ledger.count);
        }
        table.row(vec![
            n.to_string(),
            fmt_ms(makespans[0]),
            fmt_ms(makespans[1]),
            fmt_ms(makespans[2]),
            transfers[0].to_string(),
            transfers[1].to_string(),
            transfers[2].to_string(),
        ]);
        // Paper shape: close performance; gp minimal transfers.
        let max = makespans.iter().cloned().fold(0.0f64, f64::max);
        let min = makespans.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 2.5, "MA makespans should be close at {n}: {makespans:?}");
        if n >= 512 {
            let best_online = transfers[0].min(transfers[1]);
            assert!(transfers[2] <= best_online + 2,
                "gp transfers must be near-minimal at {n}: {transfers:?}");
        }
    }
    let wall = wall0.elapsed().as_secs_f64();
    println!("{}", table.render());
    println!(
        "sim throughput: {:.0} task-events/s ({} events in {:.2}s)",
        events as f64 / wall,
        events,
        wall
    );
    match table.save_csv("fig5_ma_schedulers") {
        Ok(p) => println!("csv: {}", p.display()),
        Err(e) => eprintln!("csv save failed: {e}"),
    }
    println!("shape check: policies close; gp minimal transfers — OK");
}
