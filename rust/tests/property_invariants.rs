//! Randomized property tests over coordinator/scheduler/partitioner
//! invariants (proptest is unavailable offline; the in-tree PCG + forall
//! loop plays its role — every failure prints the offending seed).

use hetsched::dag::{generate_layered, metis_io, topo, Dag, GeneratorConfig, KernelKind};
use hetsched::partition::{partition, quality, PartitionConfig};
use hetsched::perfmodel::{CalibratedModel, PerfModel};
use hetsched::platform::Platform;
use hetsched::sched;
use hetsched::sim::{simulate, SimConfig};
use hetsched::util::Pcg32;

const SCHEDULERS: [&str; 7] = ["eager", "dmda", "gp", "heft", "random", "roundrobin", "gpu-only"];

fn random_dag(rng: &mut Pcg32) -> Dag {
    let kernels = rng.gen_range_usize(2, 120);
    let kernel = *rng.choose(&[KernelKind::Ma, KernelKind::Mm, KernelKind::MmAdd]);
    let size = *rng.choose(&[64u32, 256, 512, 1024, 2048]);
    let mut cfg = GeneratorConfig::scaled(kernels, kernel, size, rng.next_u64());
    // Vary density within feasibility.
    cfg.edges = cfg.edges.min(kernels * (kernels - 1) / 4).max(kernels.saturating_sub(1));
    generate_layered(&cfg)
}

/// Every schedule respects dependencies, assigns all tasks, and never
/// beats the critical-path lower bound.
#[test]
fn forall_schedules_are_feasible_and_bounded() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    let mut rng = Pcg32::seeded(0xFEED);
    for trial in 0..40 {
        let seed_note = format!("trial {trial}");
        let dag = random_dag(&mut rng);
        let cp = topo::critical_path(
            &dag,
            |v| {
                let n = dag.node(v);
                model
                    .kernel_time_ms(n.kernel, n.size, 0)
                    .min(model.kernel_time_ms(n.kernel, n.size, 1))
            },
            |_| 0.0,
        );
        for name in SCHEDULERS {
            let mut s = sched::by_name(name).unwrap();
            let cfg = SimConfig {
                return_results_to_host: false,
                collect_trace: true,
                ..Default::default()
            };
            let r = simulate(&dag, s.as_mut(), &platform, &model, &cfg);
            assert!(
                r.makespan_ms >= cp - 1e-9,
                "{seed_note} {name}: makespan {} < critical path {cp}",
                r.makespan_ms
            );
            assert!(r.assignments.iter().all(|&d| d < 2), "{seed_note} {name}");
            // Trace respects every edge.
            let mut end = vec![0.0f64; dag.node_count()];
            let mut start = vec![0.0f64; dag.node_count()];
            for ev in &r.trace {
                start[ev.task] = ev.start_ms;
                end[ev.task] = ev.end_ms;
            }
            for (_, e) in dag.edges() {
                assert!(
                    end[e.src] <= start[e.dst] + 1e-9,
                    "{seed_note} {name}: edge {}->{} violated",
                    e.src,
                    e.dst
                );
            }
        }
    }
}

/// Transfer counts are bounded by the structural maximum: every input
/// fetched once per consumer plus one write-back per sink.
#[test]
fn forall_transfer_counts_bounded() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    let mut rng = Pcg32::seeded(0xBEEF);
    for trial in 0..30 {
        let dag = random_dag(&mut rng);
        let max_inputs: usize = dag
            .nodes()
            .map(|(v, n)| dag.in_degree(v).max(n.kernel.arity()))
            .sum();
        let bound = (max_inputs + dag.sinks().len()) as u64;
        for name in SCHEDULERS {
            let mut s = sched::by_name(name).unwrap();
            let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
            assert!(
                r.ledger.count <= bound,
                "trial {trial} {name}: {} transfers exceeds bound {bound}",
                r.ledger.count
            );
        }
    }
}

/// Pinning everything on one device yields zero inter-kernel transfers
/// (only initial loads + final stores), for any DAG.
#[test]
fn forall_single_device_transfer_floor() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    let mut rng = Pcg32::seeded(0xCAFE);
    for _ in 0..20 {
        let dag = random_dag(&mut rng);
        let mut s = sched::by_name("cpu-only").unwrap();
        let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
        assert_eq!(r.ledger.count, 0, "cpu-only must never touch the bus");
        let mut s = sched::by_name("gpu-only").unwrap();
        let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
        // gpu-only: initial loads (missing-arity inputs of entry kernels
        // + all initial buffers) + one write-back per sink; inter-kernel
        // edges stay device-resident.
        let initial_loads: usize = dag
            .nodes()
            .map(|(v, n)| n.kernel.arity().saturating_sub(dag.in_degree(v)))
            .sum();
        let expected = (initial_loads + dag.sinks().len()) as u64;
        assert_eq!(r.ledger.count, expected, "gpu-only transfer floor");
    }
}

/// The partitioner always returns a complete, in-range partition whose
/// reported cut matches a from-scratch recount, for random graphs,
/// random k and random targets.
#[test]
fn forall_partitions_consistent() {
    let mut rng = Pcg32::seeded(0xD00D);
    for trial in 0..40 {
        let n = rng.gen_range_usize(1, 400);
        // Random connected-ish graph.
        let mut adj: Vec<Vec<(usize, i64)>> = vec![Vec::new(); n];
        for v in 1..n {
            let u = rng.gen_range_usize(0, v);
            let w = 1 + rng.gen_range(20) as i64;
            adj[v].push((u, w));
            adj[u].push((v, w));
        }
        for _ in 0..n / 2 {
            let a = rng.gen_range_usize(0, n);
            let b = rng.gen_range_usize(0, n);
            if a != b && !adj[a].iter().any(|&(x, _)| x == b) {
                let w = 1 + rng.gen_range(20) as i64;
                adj[a].push((b, w));
                adj[b].push((a, w));
            }
        }
        let vwgt: Vec<i64> = (0..n).map(|_| 1 + rng.gen_range(9) as i64).collect();
        let g = metis_io::MetisGraph::from_adj(vwgt, adj);

        let k = rng.gen_range_usize(1, 5.min(n + 1));
        let targets: Option<Vec<f64>> = if rng.gen_bool(0.5) {
            let raw: Vec<f64> = (0..k).map(|_| 0.05 + rng.gen_f64()).collect();
            let s: f64 = raw.iter().sum();
            Some(raw.iter().map(|x| x / s).collect())
        } else {
            None
        };
        let cfg = PartitionConfig { k, targets, seed: rng.next_u64(), ..Default::default() };
        let res = partition(&g, &cfg);
        assert_eq!(res.parts.len(), n, "trial {trial}");
        assert!(res.parts.iter().all(|&p| p < k), "trial {trial}: part out of range");
        assert_eq!(
            res.edge_cut,
            quality::edge_cut(&g, &res.parts),
            "trial {trial}: reported cut must match recount"
        );
        let w = quality::part_weights(&g, &res.parts, k);
        assert_eq!(w, res.part_weights, "trial {trial}");
        assert_eq!(w.iter().sum::<i64>(), g.vwgt.iter().sum::<i64>());
    }
}

/// Fixed-vertex pins are always honored.
#[test]
fn forall_fixed_vertices_respected() {
    let mut rng = Pcg32::seeded(0xF17ED);
    for trial in 0..25 {
        let n = rng.gen_range_usize(4, 200);
        let mut adj: Vec<Vec<(usize, i64)>> = vec![Vec::new(); n];
        for v in 1..n {
            let u = rng.gen_range_usize(0, v);
            adj[v].push((u, 1 + rng.gen_range(8) as i64));
            let w = adj[v][adj[v].len() - 1].1;
            adj[u].push((v, w));
        }
        let g = metis_io::MetisGraph::from_adj(vec![1; n], adj);
        let mut fixed = vec![-1i32; n];
        for _ in 0..rng.gen_range_usize(1, 1 + n / 4) {
            let v = rng.gen_range_usize(0, n);
            fixed[v] = rng.gen_range(2) as i32;
        }
        let cfg = PartitionConfig { fixed: Some(fixed.clone()), seed: trial, ..Default::default() };
        let res = partition(&g, &cfg);
        for v in 0..n {
            if fixed[v] >= 0 {
                assert_eq!(res.parts[v], fixed[v] as usize, "trial {trial}: pin violated at {v}");
            }
        }
    }
}

/// DOT writer output always reparses to an isomorphic graph.
#[test]
fn forall_dot_roundtrip() {
    let mut rng = Pcg32::seeded(0xD07);
    for _ in 0..25 {
        let dag = random_dag(&mut rng);
        let text = hetsched::dag::dot::write(&dag, "g", None);
        let p = hetsched::dag::dot::parse(&text, 1).unwrap();
        assert_eq!(p.dag.node_count(), dag.node_count());
        assert_eq!(p.dag.edge_count(), dag.edge_count());
        for (id, n) in dag.nodes() {
            let rid = p.dag.node_by_name(&n.name).unwrap();
            assert_eq!(p.dag.node(rid).kernel, n.kernel);
            assert_eq!(p.dag.node(rid).size, n.size);
            let _ = id;
        }
    }
}

/// CSR construction round-trips `dag_to_metis`: for random weighted
/// digraphs (antiparallel edges included), the CSR graph matches a
/// from-scratch per-vertex-HashMap symmetrization (the seed
/// implementation's construction), is structurally symmetric, merges
/// antiparallel duplicates, and its degree sums equal twice the edge
/// count.
#[test]
fn forall_csr_construction_roundtrips() {
    use std::collections::HashMap;
    let mut rng = Pcg32::seeded(0xC52);
    for trial in 0..40 {
        // Random digraph over a Dag shell; ~1/8 of edges get an
        // antiparallel twin so duplicate merging is always exercised.
        let n = rng.gen_range_usize(2, 60);
        let mut dag = hetsched::dag::Dag::new();
        for i in 0..n {
            dag.add_node(format!("n{i}"), KernelKind::Ma, 64);
        }
        let m = rng.gen_range_usize(1, 3 * n);
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        for _ in 0..m {
            let a = rng.gen_range_usize(0, n);
            let b = rng.gen_range_usize(0, n);
            if a == b {
                continue;
            }
            dag.add_edge(a, b);
            pairs.push((a, b));
            if rng.gen_bool(0.125) {
                dag.add_edge(b, a);
                pairs.push((b, a));
            }
        }
        let edge_w = |e: hetsched::dag::EdgeId| 1 + (e as i64 * 7) % 13;
        let node_w = |v: hetsched::dag::NodeId| 1 + v as i64;
        let g = metis_io::dag_to_metis(&dag, node_w, edge_w);

        // Reference: the seed's HashMap-merged symmetrization.
        let mut merged: Vec<HashMap<usize, i64>> = vec![HashMap::new(); n];
        for (eid, &(a, b)) in pairs.iter().enumerate() {
            let w = edge_w(eid).max(1);
            *merged[a].entry(b).or_insert(0) += w;
            *merged[b].entry(a).or_insert(0) += w;
        }
        let mut undirected = 0usize;
        for v in 0..n {
            let mut want: Vec<(usize, i64)> = merged[v].iter().map(|(&u, &w)| (u, w)).collect();
            want.sort_unstable();
            let got: Vec<(usize, i64)> = g.neighbors(v).collect();
            assert_eq!(got, want, "trial {trial}: vertex {v} adjacency mismatch");
            undirected += want.len();
            assert_eq!(g.vwgt[v], node_w(v), "trial {trial}: vwgt {v}");
        }
        // Degree sum = directed entry count = 2 * undirected edges.
        assert_eq!(undirected, g.adjncy.len(), "trial {trial}: degree sum");
        assert_eq!(g.edge_count() * 2, g.adjncy.len(), "trial {trial}: edge count");
        // Symmetry with equal weights.
        for v in 0..n {
            for (u, w) in g.neighbors(v) {
                assert!(
                    g.neighbors(u).any(|(x, xw)| x == v && xw == w),
                    "trial {trial}: asymmetric {v}<->{u}"
                );
            }
        }
        // Text roundtrip preserves the CSR exactly.
        let text = metis_io::write_metis(&g);
        assert_eq!(metis_io::parse_metis(&text).unwrap(), g, "trial {trial}: text roundtrip");
    }
}

/// Class-partitioned nearest-rank percentiles recombine consistently
/// with the session-wide ones: for random sessions (random class
/// counts, timings, deadlines, rejection flags), every class p50 lies
/// within that class's own sojourn min/max, class percentiles are
/// monotone (p50 ≤ p95 ≤ p99), class job counts partition the session,
/// deadline-hit rates live in [0, 1], and the session percentile is
/// bracketed by the per-class extremes.
#[test]
fn forall_per_class_percentiles_recombine() {
    use hetsched::data::TransferLedger;
    use hetsched::sim::{JobTiming, RunReport, SessionReport};
    let empty_job = || RunReport {
        scheduler: "test",
        makespan_ms: 0.0,
        ledger: TransferLedger::new(),
        assignments: vec![],
        device_busy_ms: vec![],
        tasks_per_device: vec![],
        decision_ns: 0,
        plan_ns: 0,
        trace: vec![],
    };
    let mut rng = Pcg32::seeded(0xC1A55);
    for trial in 0..40 {
        let n_classes = rng.gen_range_usize(1, 5);
        let n_jobs = rng.gen_range_usize(1, 40);
        let mut s = SessionReport::new("test");
        s.class_names = (0..n_classes).map(|c| format!("c{c}")).collect();
        for _ in 0..n_jobs {
            let submit = rng.gen_f64() * 50.0;
            let wait = rng.gen_f64() * 5.0;
            let service = 0.1 + rng.gen_f64() * 20.0;
            let rejected = rng.gen_bool(0.15);
            let complete = if rejected { submit + wait } else { submit + wait + service };
            s.push_timed(
                empty_job(),
                false,
                JobTiming {
                    submit_ms: submit,
                    admit_ms: submit + wait,
                    complete_ms: complete,
                    class: rng.gen_range_usize(0, n_classes),
                    priority: rng.gen_range(3),
                    deadline_ms: if rng.gen_bool(0.5) {
                        submit + rng.gen_f64() * 25.0
                    } else {
                        f64::INFINITY
                    },
                    rejected,
                },
            );
        }
        let per = s.per_class();
        assert_eq!(per.len(), s.class_count(), "trial {trial}");
        assert_eq!(
            per.iter().map(|c| c.jobs).sum::<usize>(),
            s.job_count(),
            "trial {trial}: class jobs must partition the session"
        );
        assert_eq!(
            per.iter().map(|c| c.rejected).sum::<usize>(),
            s.rejected_count(),
            "trial {trial}: class rejections must partition the session"
        );
        let mut class_mins = Vec::new();
        let mut class_maxs = Vec::new();
        for c in &per {
            assert!((0.0..=1.0).contains(&c.deadline_hit_rate), "trial {trial}: {c:?}");
            assert!(
                c.p50_sojourn_ms <= c.p95_sojourn_ms + 1e-12
                    && c.p95_sojourn_ms <= c.p99_sojourn_ms + 1e-12,
                "trial {trial}: class percentiles must be monotone: {c:?}"
            );
            // Recompute the class's served sojourns from the timings.
            let sojourns: Vec<f64> = s
                .timings
                .iter()
                .filter(|t| t.class == c.class && !t.rejected)
                .map(|t| t.sojourn_ms())
                .collect();
            assert_eq!(sojourns.len() + c.rejected, c.jobs, "trial {trial}");
            if sojourns.is_empty() {
                assert_eq!(c.p50_sojourn_ms, 0.0, "trial {trial}: empty class");
                continue;
            }
            let lo = sojourns.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = sojourns.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            for p in [c.p50_sojourn_ms, c.p95_sojourn_ms, c.p99_sojourn_ms] {
                assert!(
                    (lo - 1e-12..=hi + 1e-12).contains(&p),
                    "trial {trial}: class {c:?} percentile {p} outside [{lo}, {hi}]"
                );
                assert!(
                    sojourns.iter().any(|&x| (x - p).abs() < 1e-12),
                    "trial {trial}: nearest-rank value must be an observed sojourn"
                );
            }
            assert!(
                c.mean_sojourn_ms >= lo - 1e-12 && c.mean_sojourn_ms <= hi + 1e-12,
                "trial {trial}"
            );
            class_mins.push(lo);
            class_maxs.push(hi);
        }
        // Session-wide percentiles are bracketed by class extremes, and
        // the session deadline-hit rate is in range.
        if !class_mins.is_empty() {
            let lo = class_mins.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = class_maxs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            for p in [s.p50_sojourn_ms(), s.p95_sojourn_ms(), s.p99_sojourn_ms()] {
                assert!((lo - 1e-12..=hi + 1e-12).contains(&p), "trial {trial}");
            }
        }
        assert!((0.0..=1.0).contains(&s.deadline_hit_rate()), "trial {trial}");
    }
}

/// Workload ratios always form a probability vector, and Formula (1)
/// holds pairwise for two devices.
#[test]
fn forall_formula1_probability_vector() {
    let model = CalibratedModel::paper();
    let platform = Platform::paper();
    let mut rng = Pcg32::seeded(0xF0);
    for _ in 0..50 {
        let kernel = *rng.choose(&[KernelKind::Ma, KernelKind::Mm, KernelKind::MmAdd]);
        let n = 32 + rng.gen_range(4000);
        let r = model.workload_ratios(kernel, n, &platform);
        assert!((r.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(r.iter().all(|&x| (0.0..=1.0).contains(&x)));
        let t0 = model.kernel_time_ms(kernel, n, 0);
        let t1 = model.kernel_time_ms(kernel, n, 1);
        assert!((r[0] - t1 / (t0 + t1)).abs() < 1e-9, "Formula (1) violated");
    }
}

/// Welford replication statistics: any chunking of a sample merged in
/// any order agrees with the sequential accumulation (within fp
/// tolerance), the CI half-width tightens as samples grow on a fixed
/// spread, and one sample degenerates to an error-bar-free point.
#[test]
fn forall_welford_merge_invariance() {
    use hetsched::util::stats::Welford;
    let mut rng = Pcg32::seeded(0x57A7);
    for trial in 0..50 {
        let n = rng.gen_range_usize(2, 200);
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_f64() * 500.0 - 100.0).collect();
        let mut seq = Welford::new();
        xs.iter().for_each(|&x| seq.push(x));
        // Random chunking, merged in shuffled chunk order.
        let mut chunks: Vec<Welford> = Vec::new();
        let mut i = 0;
        while i < n {
            let take = rng.gen_range_usize(1, (n - i).min(20) + 1);
            let mut w = Welford::new();
            xs[i..i + take].iter().for_each(|&x| w.push(x));
            chunks.push(w);
            i += take;
        }
        // Fisher-Yates shuffle of the chunk order.
        for j in (1..chunks.len()).rev() {
            let k = rng.gen_range_usize(0, j + 1);
            chunks.swap(j, k);
        }
        let mut merged = Welford::new();
        chunks.iter().for_each(|w| merged.merge(w));
        assert_eq!(merged.count(), seq.count(), "trial {trial}");
        assert!((merged.mean() - seq.mean()).abs() < 1e-9, "trial {trial}: mean drift");
        assert!(
            (merged.variance() - seq.variance()).abs() < 1e-6 * (1.0 + seq.variance()),
            "trial {trial}: variance drift ({} vs {})",
            merged.variance(),
            seq.variance()
        );
        // One sample: point estimate, no error bar.
        let mut single = Welford::new();
        single.push(xs[0]);
        assert_eq!(single.mean(), xs[0], "trial {trial}");
        assert_eq!(single.ci95_half_width(), 0.0, "trial {trial}");
        // Fixed spread, more samples: the t-interval tightens. Repeat
        // the same sample 4x so mean/std are identical but n grows.
        if seq.count() >= 2 && seq.stddev() > 0.0 {
            let mut grown = seq;
            for _ in 0..3 {
                grown.merge(&seq);
            }
            assert!(
                grown.ci95_half_width() < seq.ci95_half_width(),
                "trial {trial}: CI failed to shrink with n"
            );
        }
    }
}
