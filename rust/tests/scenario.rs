//! Scenario subsystem guard-rails.
//!
//! Three contracts, in order of importance:
//!
//! 1. **Bench bit-identity** — the committed `scenarios/*.toml` files
//!    replaced the hard-coded `bench stream` flag tuples of PRs 4–6, so
//!    repetition 0 of each builtin cell must reproduce the old
//!    hard-coded runs *exactly* (the old constants are transcribed
//!    below and the two paths compared metric by metric).
//! 2. **Replication determinism** — the merged `ScenarioReport` is
//!    bit-identical at 1, 2 and 8 worker threads, repetition `i` of the
//!    threaded fan-out equals a standalone `run_repetition(i)` call,
//!    and derived per-repetition seeds never collide.
//! 3. **The statistics acceptance headline** — at 20 repetitions of
//!    `open-qos`, the fifo and edf deadline-hit 95% confidence
//!    intervals do not overlap: the PR 5 headline (0.72 vs 1.00) is
//!    significant, not a lucky seed.

use hetsched::dag::{workloads, Dag};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::scenario::{
    load_builtin, rep_seed, run_cell, run_repetition, run_scenario, RunOptions, ScenarioSpec,
};
use hetsched::sched::{self, PlanCache};
use hetsched::sim::{
    simulate_open, simulate_open_qos, FaultSpec, JobQos, SessionReport, SimConfig, StreamConfig,
};

// --- the PR 4-6 hard-coded bench tuples, transcribed ----------------

const OLD_OPEN_STREAM: &str = "stream:arrival=poisson,rate=220,queue=8";
const OLD_QOS_STREAM: &str = "stream:arrival=bursty,rate=380,burst=8,queue=2,seed=7";
const OLD_QOS_POLICY: &str = "dmda";
const OLD_FAULT: &str = "fault:at=60:dev=1:down=40;refetch=2";
const OLD_OPEN_JOBS: usize = 24;
const OLD_SEED: u64 = 2015;

fn old_open_phased() -> Vec<Dag> {
    (0..OLD_OPEN_JOBS).map(|_| workloads::phased(8, 4, 256)).collect()
}

fn run_old_open(dags: &[Dag], policy: &str, stream: &StreamConfig, fault: Option<FaultSpec>) -> SessionReport {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    let mut s = sched::by_name(policy).unwrap();
    let mut cache = PlanCache::new();
    let config = SimConfig { fault, ..Default::default() };
    simulate_open(dags, s.as_mut(), &platform, &model, &config, stream, &mut cache)
}

/// Metric-by-metric exact equality between two engine runs.
fn assert_metrics_identical(a: &SessionReport, b: &SessionReport, what: &str) {
    for ((name, va), (_, vb)) in a.scalar_metrics().iter().zip(b.scalar_metrics().iter()) {
        assert_eq!(va, vb, "{what}: metric {name} drifted");
    }
    assert_eq!(a.ledger.count, b.ledger.count, "{what}: transfer count drifted");
    assert_eq!(a.job_count(), b.job_count(), "{what}: job count drifted");
}

#[test]
fn open_poisson_rep0_matches_the_old_hardcoded_bench() {
    let spec = load_builtin("open-poisson").unwrap();
    assert_eq!((spec.jobs, spec.seed), (OLD_OPEN_JOBS, OLD_SEED));
    assert_eq!(spec.stream_axis, [OLD_OPEN_STREAM]);
    let dags = old_open_phased();
    let stream = StreamConfig::from_spec(OLD_OPEN_STREAM).unwrap();
    for cell in spec.cells().unwrap() {
        let old = run_old_open(&dags, &cell.scheduler, &stream, None);
        let new = run_repetition(&spec, &cell, 0).unwrap();
        assert_metrics_identical(&old, &new, &format!("open-poisson {}", cell.label));
    }
}

#[test]
fn open_fault_rep0_matches_the_old_hardcoded_bench() {
    let spec = load_builtin("open-fault").unwrap();
    assert_eq!(spec.fault.as_ref().unwrap().spec_string(), OLD_FAULT);
    let dags = old_open_phased();
    let stream = StreamConfig::from_spec(OLD_OPEN_STREAM).unwrap();
    let fault = FaultSpec::from_spec(OLD_FAULT).unwrap();
    for cell in spec.cells().unwrap() {
        let old = run_old_open(&dags, &cell.scheduler, &stream, Some(fault.clone()));
        let new = run_repetition(&spec, &cell, 0).unwrap();
        assert_metrics_identical(&old, &new, &format!("open-fault {}", cell.label));
        assert!(new.failures_injected > 0, "scripted kill must fire in every repetition");
    }
}

#[test]
fn open_qos_rep0_matches_the_old_hardcoded_bench() {
    let spec = load_builtin("open-qos").unwrap();
    let classes = workloads::default_qos_mix();
    assert_eq!(spec.classes, classes);
    let classed = workloads::job_classes(&classes, OLD_OPEN_JOBS, OLD_SEED);
    let dags: Vec<Dag> = classed.iter().map(|j| j.dag.clone()).collect();
    let qos: Vec<JobQos> = classed.iter().map(|j| j.qos).collect();
    let names = workloads::class_names(&classes);
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    for cell in spec.cells().unwrap() {
        let stream_spec = if cell.admit == "fifo" {
            OLD_QOS_STREAM.to_string()
        } else {
            format!("{OLD_QOS_STREAM},admit={}", cell.admit)
        };
        let stream = StreamConfig::from_spec(&stream_spec).unwrap();
        let mut s = sched::by_name(OLD_QOS_POLICY).unwrap();
        let mut cache = PlanCache::new();
        let old = simulate_open_qos(
            &dags,
            &qos,
            &names,
            s.as_mut(),
            &platform,
            &model,
            &SimConfig::default(),
            &stream,
            &mut cache,
        );
        let new = run_repetition(&spec, &cell, 0).unwrap();
        assert_metrics_identical(&old, &new, &format!("open-qos {}", cell.label));
    }
}

// --- replication determinism ----------------------------------------

#[test]
fn merged_report_is_thread_count_invariant() {
    let spec = load_builtin("open-qos").unwrap();
    let run = |threads: usize| {
        run_scenario(&spec, &RunOptions { repetitions: Some(6), threads }).unwrap()
    };
    let one = run(1);
    let two = run(2);
    let eight = run(8);
    assert_eq!(one, two, "1-thread vs 2-thread merged reports diverged");
    assert_eq!(one, eight, "1-thread vs 8-thread merged reports diverged");
}

#[test]
fn fanned_out_repetition_equals_standalone_run() {
    let spec = load_builtin("open-poisson").unwrap();
    let cell = &spec.cells().unwrap()[1]; // dmda
    let fanned = run_cell(&spec, cell, 5, 3).unwrap();
    assert_eq!(fanned.len(), 5);
    for (rep, session) in fanned.iter().enumerate() {
        let standalone = run_repetition(&spec, cell, rep).unwrap();
        assert_metrics_identical(session, &standalone, &format!("repetition {rep}"));
    }
}

#[test]
fn repetitions_actually_vary_and_seeds_never_collide() {
    // Repetition 0 keeps the base seed on every axis (the bit-identity
    // contract), so uniqueness is claimed across the base plus every
    // derived (rep >= 1) seed.
    let mut seen = std::collections::BTreeSet::new();
    for axis in 0..3u64 {
        assert_eq!(rep_seed(OLD_SEED, 0, axis), OLD_SEED, "rep 0 must keep the base seed");
    }
    seen.insert(OLD_SEED);
    for rep in 1..8 {
        for axis in 0..3u64 {
            assert!(seen.insert(rep_seed(OLD_SEED, rep, axis)), "seed collision at {rep}/{axis}");
        }
    }
    // Same base seeds, different repetitions: the sojourn distribution
    // must actually change (otherwise the CI would be a lie).
    let spec = load_builtin("open-poisson").unwrap();
    let cell = &spec.cells().unwrap()[1];
    let r0 = run_repetition(&spec, cell, 0).unwrap();
    let r1 = run_repetition(&spec, cell, 1).unwrap();
    assert_ne!(
        r0.mean_sojourn_ms(),
        r1.mean_sojourn_ms(),
        "derived seeds produced identical repetitions"
    );
}

#[test]
fn single_repetition_degenerates_to_a_point_estimate() {
    let spec = load_builtin("open-poisson").unwrap();
    let report = run_scenario(&spec, &RunOptions { repetitions: Some(1), threads: 2 }).unwrap();
    assert_eq!(report.repetitions, 1);
    let cell = &report.cells[1];
    let rep0 = run_repetition(&spec, &spec.cells().unwrap()[1], 0).unwrap();
    for (name, value) in rep0.scalar_metrics() {
        let stat = cell.metric(name).unwrap();
        assert_eq!(stat.n, 1);
        assert_eq!(stat.mean, value, "{name}: point estimate must be the rep-0 value");
        assert_eq!((stat.std, stat.ci95), (0.0, 0.0), "{name}: no error bar from one sample");
    }
}

#[test]
fn bad_scheduler_specs_fail_before_any_simulation() {
    let spec = ScenarioSpec::parse(
        "[scenario]\nname = t\njobs = 2\n[sweep]\nscheduler = \"gp|warp-drive\"\n",
    )
    .unwrap();
    let err = run_scenario(&spec, &RunOptions::default()).unwrap_err().to_string();
    assert!(err.contains("warp-drive"), "{err}");
}

// --- the statistics acceptance headline ------------------------------

#[test]
fn open_qos_fifo_vs_edf_deadline_cis_are_disjoint_at_20_reps() {
    let spec = load_builtin("open-qos").unwrap();
    assert_eq!(spec.repetitions, 20, "committed repetition count is the acceptance pin");
    let report = run_scenario(&spec, &RunOptions::default()).unwrap();
    let fifo = report.cell("dmda+fifo").unwrap().metric("deadline_hit_rate").unwrap();
    let edf = report.cell("dmda+edf").unwrap().metric("deadline_hit_rate").unwrap();
    assert!(
        edf.mean > fifo.mean,
        "edf must beat fifo on deadline hits ({} vs {})",
        edf.mean,
        fifo.mean
    );
    assert!(
        fifo.disjoint_from(&edf),
        "fifo [{}, {}] vs edf [{}, {}] overlap: headline not significant",
        fifo.lo(),
        fifo.hi(),
        edf.lo(),
        edf.hi()
    );
    // Per-class SLOs surface in the merged report with matching arity.
    for cell in &report.cells {
        assert_eq!(cell.classes.len(), 3, "interactive/standard/batch breakdown");
        assert_eq!(cell.repetitions, 20);
        for (_, stat) in &cell.metrics {
            assert_eq!(stat.n, 20);
            assert!(stat.std >= 0.0 && stat.ci95 >= 0.0);
        }
    }
}
