//! CSR-partitioner parity: the flat-CSR substrate (bucket-gain FM,
//! view-based recursive bisection, workspace reuse) must reproduce the
//! seed implementation's results on the historical test corpus —
//! two-clique bridges, ring-connected clique k-way splits, and weighted
//! paths — under fixed seeds, and must be bit-deterministic across
//! repeated runs and workspace reuse.

use hetsched::dag::metis_io::MetisGraph;
use hetsched::partition::{partition, partition_with, quality, PartitionConfig, PartitionWorkspace};

/// Two dense cliques joined by a single light edge (the seed corpus
/// graph from `partition::tests`).
fn two_cliques(sz: usize, heavy: i64, light: i64) -> MetisGraph {
    let n = 2 * sz;
    let mut adj = vec![Vec::new(); n];
    for c in 0..2 {
        for i in 0..sz {
            for j in 0..sz {
                if i != j {
                    adj[c * sz + i].push((c * sz + j, heavy));
                }
            }
        }
    }
    adj[0].push((sz, light));
    adj[sz].push((0, light));
    MetisGraph::from_adj(vec![1; n], adj)
}

/// 4 cliques of `sz`, ring-connected by unit edges (seed corpus).
fn four_cliques(sz: usize) -> MetisGraph {
    let n = 4 * sz;
    let mut adj = vec![Vec::new(); n];
    for c in 0..4 {
        for i in 0..sz {
            for j in 0..sz {
                if i != j {
                    adj[c * sz + i].push((c * sz + j, 20));
                }
            }
        }
    }
    for c in 0..4 {
        let a = c * sz;
        let b = ((c + 1) % 4) * sz;
        adj[a].push((b, 1));
        adj[b].push((a, 1));
    }
    MetisGraph::from_adj(vec![1; n], adj)
}

fn path(n: usize, w: i64) -> MetisGraph {
    let mut adj = vec![Vec::new(); n];
    for i in 0..n - 1 {
        adj[i].push((i + 1, w));
        adj[i + 1].push((i, w));
    }
    MetisGraph::from_adj(vec![1; n], adj)
}

/// The seed implementation's pinned outcomes on `two_cliques(8, 10, 1)`:
/// exactly the bridge is cut, parts are the two cliques, weights 8/8.
#[test]
fn parity_two_cliques_bridge_cut() {
    let g = two_cliques(8, 10, 1);
    let res = partition(&g, &PartitionConfig::default());
    assert_eq!(res.edge_cut, 1, "seed cut only the light bridge");
    assert_eq!(res.part_weights, vec![8, 8]);
    assert!(res.parts[..8].iter().all(|&p| p == res.parts[0]));
    assert!(res.parts[8..].iter().all(|&p| p == res.parts[8]));
    assert_ne!(res.parts[0], res.parts[8]);
}

/// Seed outcome on the k=4 clique ring (seed 3): perfectly balanced
/// parts, only ring edges cut, cliques kept whole.
#[test]
fn parity_kway_four_cliques() {
    let sz = 6;
    let g = four_cliques(sz);
    let res = partition(&g, &PartitionConfig { k: 4, seed: 3, ..Default::default() });
    assert_eq!(res.part_weights, vec![sz as i64; 4]);
    assert!(res.edge_cut <= 4, "cut {} exceeds the ring", res.edge_cut);
    for c in 0..4 {
        let p0 = res.parts[c * sz];
        assert!((0..sz).all(|i| res.parts[c * sz + i] == p0), "clique {c} split");
    }
}

/// Seed outcomes on paths: a balanced bisection of a path cuts ~1 edge;
/// a 1:2 split respects the target within the seed's tolerance.
#[test]
fn parity_paths() {
    let g = path(64, 5);
    let res = partition(&g, &PartitionConfig::default());
    assert!(res.edge_cut <= 10, "path bisection cut {} too high", res.edge_cut);
    let f = res.fractions();
    assert!((f[0] - 0.5).abs() < 0.1, "path split fractions {f:?}");

    let g = path(30, 1);
    let res = partition(&g, &PartitionConfig::bipartition(1.0 / 3.0, 2.0 / 3.0));
    let f = res.fractions();
    assert!((f[0] - 1.0 / 3.0).abs() < 0.12, "got fractions {f:?}");
    assert!(res.edge_cut <= 3, "cut {} too high for a path", res.edge_cut);
}

/// Fixed seed => bit-identical parts, across runs AND across workspace
/// reuse, on the whole corpus.
#[test]
fn fixed_seed_determinism_with_and_without_workspace() {
    let corpus: Vec<(MetisGraph, PartitionConfig)> = vec![
        (two_cliques(8, 10, 1), PartitionConfig::default()),
        (two_cliques(10, 5, 1), PartitionConfig { seed: 42, ..Default::default() }),
        (four_cliques(6), PartitionConfig { k: 4, seed: 3, ..Default::default() }),
        (path(30, 1), PartitionConfig::bipartition(1.0 / 3.0, 2.0 / 3.0)),
        (path(200, 2), PartitionConfig { k: 3, seed: 9, ..Default::default() }),
    ];
    let mut ws = PartitionWorkspace::new();
    for (i, (g, cfg)) in corpus.iter().enumerate() {
        let a = partition(g, cfg);
        let b = partition(g, cfg);
        assert_eq!(a.parts, b.parts, "case {i}: rerun differs");
        // Workspace-reusing runs interleaved with other problems must
        // still match the fresh-workspace result exactly.
        let c = partition_with(g, cfg, &mut ws);
        assert_eq!(a.parts, c.parts, "case {i}: workspace reuse differs");
        assert_eq!(a.edge_cut, c.edge_cut, "case {i}: cut differs");
        assert_eq!(a.part_weights, c.part_weights, "case {i}: weights differ");
        // Reported metrics are recounts, not stale accumulators.
        assert_eq!(a.edge_cut, quality::edge_cut(g, &a.parts), "case {i}");
        assert_eq!(
            a.part_weights,
            quality::part_weights(g, &a.parts, cfg.k),
            "case {i}"
        );
    }
    // Second sweep over the same corpus with the warm workspace.
    for (i, (g, cfg)) in corpus.iter().enumerate() {
        let a = partition(g, cfg);
        let c = partition_with(g, cfg, &mut ws);
        assert_eq!(a.parts, c.parts, "case {i}: warm workspace differs");
    }
}

/// Parallel recursive bisection (scoped-thread fork with derived
/// per-node RNG streams) must reproduce the sequential path exactly on
/// the seed corpus — small graphs (below the fork threshold, trivially
/// equal) and a large k-way ring that actually forks.
#[test]
fn parallel_bisection_parity_on_corpus() {
    let big = {
        // 4 cliques of 300 ring-connected: forks at the top level.
        let sz = 300;
        let n = 4 * sz;
        let mut adj = vec![Vec::new(); n];
        for c in 0..4 {
            for i in 0..sz {
                for j in 0..sz {
                    if i != j {
                        adj[c * sz + i].push((c * sz + j, 20));
                    }
                }
            }
        }
        for c in 0..4 {
            let a = c * sz;
            let b = ((c + 1) % 4) * sz;
            adj[a].push((b, 1));
            adj[b].push((a, 1));
        }
        MetisGraph::from_adj(vec![1; n], adj)
    };
    let corpus: Vec<(MetisGraph, PartitionConfig)> = vec![
        (four_cliques(6), PartitionConfig { k: 4, seed: 3, ..Default::default() }),
        (path(200, 2), PartitionConfig { k: 3, seed: 9, ..Default::default() }),
        (big, PartitionConfig { k: 4, seed: 3, ..Default::default() }),
    ];
    for (i, (g, cfg)) in corpus.iter().enumerate() {
        let par = partition(g, cfg);
        let seq = partition(g, &PartitionConfig { parallel: false, ..cfg.clone() });
        assert_eq!(par.parts, seq.parts, "case {i}: parallel/sequential drift");
        assert_eq!(par.edge_cut, seq.edge_cut, "case {i}: cut drift");
        assert_eq!(par.part_weights, seq.part_weights, "case {i}: weights drift");
    }
}
