//! Integration tests across the full offline pipeline:
//! DOT text -> DAG -> weights -> partition -> pin -> simulate -> metrics,
//! plus the paper's figure shapes end-to-end.

use hetsched::dag::{dot, generate_layered, metis_io, GeneratorConfig, KernelKind};
use hetsched::perfmodel::{CalibratedModel, PerfModel};
use hetsched::platform::Platform;
use hetsched::sched::{self, GpConfig, GraphPartition};
use hetsched::sim::{simulate, SimConfig};

fn run(dag: &hetsched::dag::Dag, name: &str) -> hetsched::sim::RunReport {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    let mut s = sched::by_name(name).unwrap();
    simulate(dag, s.as_mut(), &platform, &model, &SimConfig::default())
}

#[test]
fn dot_to_schedule_pipeline() {
    // A user-authored DOT file goes all the way to a scheduled run.
    let src = r#"
        digraph pipeline {
            load1 [kernel=ma, size=512];
            load2 [kernel=ma, size=512];
            gemm1 [kernel=mm, size=512];
            gemm2 [kernel=mm, size=512];
            reduce [kernel=ma, size=512];
            load1 -> gemm1; load2 -> gemm1;
            load1 -> gemm2; load2 -> gemm2;
            gemm1 -> reduce; gemm2 -> reduce;
        }
    "#;
    let parsed = dot::parse(src, 512).unwrap();
    for name in ["eager", "dmda", "gp", "heft"] {
        let r = run(&parsed.dag, name);
        assert_eq!(r.assignments.len(), 5, "{name}");
        assert!(r.makespan_ms > 0.0, "{name}");
    }
}

#[test]
fn partition_roundtrips_through_dot() {
    // gp plan -> colored DOT -> reparse -> same pins.
    let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, 1024));
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    let mut gp = GraphPartition::new(GpConfig::default());
    gp.plan_now(&dag, &platform, &model);
    let text = dot::write(&dag, "g", Some(gp.parts()));
    let reparsed = dot::parse(&text, 1024).unwrap();
    for (id, node) in dag.nodes() {
        let rid = reparsed.dag.node_by_name(&node.name).unwrap();
        assert_eq!(reparsed.parts[rid], Some(gp.parts()[id]));
        assert_eq!(reparsed.dag.node(rid).kernel, node.kernel);
        assert_eq!(reparsed.dag.node(rid).size, node.size);
    }
}

#[test]
fn metis_file_roundtrip_of_weighted_paper_graph() {
    // The paper's format-translator path: weighted DAG -> METIS file text
    // -> parse -> identical structure.
    let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, 512));
    let model = CalibratedModel::paper();
    let g = metis_io::dag_to_metis(
        &dag,
        |v| {
            let n = dag.node(v);
            (model.kernel_time_ms(n.kernel, n.size, 1) * 1000.0) as i64
        },
        |e| (model.transfer_time_ms(dag.edge(e).bytes) * 1000.0) as i64,
    );
    let text = metis_io::write_metis(&g);
    let g2 = metis_io::parse_metis(&text).unwrap();
    assert_eq!(g, g2);
}

#[test]
fn fig5_shape_ma_policies_close() {
    for n in [512u32, 1024, 2048] {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, n));
        let e = run(&dag, "eager").makespan_ms;
        let d = run(&dag, "dmda").makespan_ms;
        let g = run(&dag, "gp").makespan_ms;
        let max = e.max(d).max(g);
        let min = e.min(d).min(g);
        assert!(max / min < 2.0, "MA@{n}: {e} {d} {g} should be comparable");
    }
}

#[test]
fn fig6_shape_eager_loses_dmda_equals_gp() {
    for n in [512u32, 1024, 2048] {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, n));
        let e = run(&dag, "eager").makespan_ms;
        let d = run(&dag, "dmda").makespan_ms;
        let g = run(&dag, "gp").makespan_ms;
        assert!(e > 2.0 * g, "MM@{n}: eager {e} must lose to gp {g}");
        assert!((d - g).abs() / g < 0.15, "MM@{n}: dmda {d} ~= gp {g}");
    }
}

#[test]
fn gp_transfer_minimality_over_sweep() {
    let mut totals = [0u64; 3];
    for n in [256u32, 512, 1024, 2048] {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Ma, n));
        for (i, name) in ["eager", "dmda", "gp"].iter().enumerate() {
            totals[i] += run(&dag, name).ledger.count;
        }
    }
    assert!(totals[2] < totals[0], "gp {totals:?} must beat eager on transfers");
    assert!(totals[2] < totals[1], "gp {totals:?} must beat dmda on transfers");
}

#[test]
fn gp_mm_large_all_gpu_formula1() {
    let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, 2048));
    let r = run(&dag, "gp");
    assert!(r.tasks_per_device[0] <= 1, "paper: CPU workload almost 0");
    // dmda makes the same decision.
    let d = run(&dag, "dmda");
    assert_eq!(d.tasks_per_device[0], 0);
}

#[test]
fn tri_device_pipeline_works() {
    let platform = Platform::tri_device();
    let model = CalibratedModel::tri_device();
    let dag = generate_layered(&GeneratorConfig::scaled(120, KernelKind::Ma, 1024, 3));
    for name in ["eager", "dmda", "gp"] {
        let mut s = sched::by_name(name).unwrap();
        let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
        assert_eq!(r.tasks_per_device.iter().sum::<usize>(), 120, "{name}");
        assert_eq!(r.tasks_per_device.len(), 3);
    }
}

#[test]
fn chrome_trace_of_real_pipeline_parses() {
    let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, 512));
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    let mut s = sched::by_name("gp").unwrap();
    let cfg = SimConfig { return_results_to_host: true, collect_trace: true, ..Default::default() };
    let r = simulate(&dag, s.as_mut(), &platform, &model, &cfg);
    let trace = hetsched::metrics::chrome_trace(&r, &platform);
    let v = hetsched::util::json::parse(&trace).unwrap();
    assert_eq!(v.as_arr().unwrap().len(), 38);
}

#[test]
fn scheduler_overhead_shape() {
    // §IV.D: gp select is a lookup; its per-task decision time must not
    // exceed dmda's by more than noise (compare medians over runs).
    let dag = generate_layered(&GeneratorConfig::scaled(1000, KernelKind::Mm, 512, 9));
    let med = |name: &str| {
        let mut xs: Vec<f64> = (0..7).map(|_| run(&dag, name).decision_ns_per_task()).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[3]
    };
    let gp = med("gp");
    let dmda = med("dmda");
    assert!(
        gp <= dmda * 3.0 + 200.0,
        "gp per-task decision ({gp} ns) should be trivial vs dmda ({dmda} ns)"
    );
}
