//! Million-job engine core guard-rails.
//!
//! Four contracts, in order of importance:
//!
//! 1. **Ladder/heap equivalence** — the ladder event queue (the
//!    default) and the `BinaryHeap` reference pop events in the same
//!    total order, so every builtin scenario and the capacity path
//!    produce bit-identical reports under either queue.
//! 2. **Capacity/classic equivalence** — below the sketch threshold,
//!    `simulate_capacity`'s slab/arena + streaming-report path equals a
//!    `simulate_open` run over the same repeated template job, metric
//!    by metric.
//! 3. **Bounded memory** — the slab recycles completed-job slots, so
//!    the engine's memory high-water mark is a function of the
//!    in-flight window, not the session length.
//! 4. **Report-path regressions** — heavily-rejecting sessions report
//!    finite metrics (no NaN, no panic), and device utilization keeps
//!    the wall-clock-span denominator.

use hetsched::dag::{workloads, KernelKind};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::scenario::{load_builtin, run_repetition_with};
use hetsched::sched::{PlanCache, SchedulerRegistry};
use hetsched::sim::{
    simulate_capacity, simulate_open, EventQueueKind, SessionReport, SimConfig, StreamConfig,
};

/// Metric-by-metric exact equality between two engine runs.
fn assert_metrics_identical(a: &SessionReport, b: &SessionReport, what: &str) {
    for ((name, va), (_, vb)) in a.scalar_metrics().iter().zip(b.scalar_metrics().iter()) {
        assert_eq!(va, vb, "{what}: metric {name} drifted");
    }
    assert_eq!(a.ledger.count, b.ledger.count, "{what}: transfer count drifted");
    assert_eq!(a.job_count(), b.job_count(), "{what}: job count drifted");
    assert_eq!(a.rejected_count(), b.rejected_count(), "{what}: rejection count drifted");
}

fn run_capacity(
    jobs: usize,
    spec: &str,
    stream_spec: &str,
    kind: EventQueueKind,
) -> SessionReport {
    let dag = workloads::chain(4, KernelKind::Mm, 256);
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    let stream = StreamConfig::from_spec(stream_spec).unwrap();
    let mut scheduler = SchedulerRegistry::builtin().create(spec).unwrap();
    let config = SimConfig { event_queue: kind, ..Default::default() };
    simulate_capacity(&dag, jobs, scheduler.as_mut(), &platform, &model, &config, &stream)
}

// --- 1. ladder/heap equivalence -------------------------------------

/// Every builtin scenario cell, repetition 0, under both queues: the
/// scenario layer covers QoS classes, admission sweeps and scripted
/// device faults, so equality here exercises every event kind the
/// engine schedules (arrivals, task readiness, rejects, device
/// down/up, drains).
#[test]
fn ladder_matches_heap_on_every_builtin_scenario() {
    for name in ["open-poisson", "open-qos", "open-fault"] {
        let spec = load_builtin(name).unwrap();
        for cell in spec.cells().unwrap() {
            let heap = run_repetition_with(&spec, &cell, 0, EventQueueKind::Heap).unwrap();
            let ladder = run_repetition_with(&spec, &cell, 0, EventQueueKind::Ladder).unwrap();
            assert_metrics_identical(&heap, &ladder, &format!("{name}/{}", cell.label));
        }
    }
}

/// The capacity path at a session long enough to make the ladder spawn
/// and retire many rungs: identical pop order means identical
/// simulated metrics *and* identical event counts.
#[test]
fn ladder_matches_heap_on_the_capacity_path() {
    let stream = "stream:arrival=poisson,rate=300,queue=8";
    let heap = run_capacity(3000, "dmda", stream, EventQueueKind::Heap);
    let ladder = run_capacity(3000, "dmda", stream, EventQueueKind::Ladder);
    assert_metrics_identical(&heap, &ladder, "capacity dmda");
    assert_eq!(heap.events_processed, ladder.events_processed, "event count drifted");
}

// --- 2. capacity/classic equivalence --------------------------------

/// Below `EXACT_SOJOURN_LIMIT` the streaming report keeps exact
/// sojourns, so `simulate_capacity` over N template jobs must equal
/// `simulate_open` over N clones of the template — same arrivals, same
/// plan reuse, same floats.
#[test]
fn capacity_engine_matches_classic_open_engine_below_threshold() {
    let dag = workloads::chain(4, KernelKind::Mm, 256);
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    let stream = StreamConfig::from_spec("stream:arrival=fixed,rate=400,queue=8").unwrap();
    let registry = SchedulerRegistry::builtin();
    for spec in ["dmda", "gp"] {
        let mut s1 = registry.create(spec).unwrap();
        let config = SimConfig::default();
        let capacity =
            simulate_capacity(&dag, 40, s1.as_mut(), &platform, &model, &config, &stream);

        let dags: Vec<_> = (0..40).map(|_| dag.clone()).collect();
        let mut s2 = registry.create(spec).unwrap();
        let mut cache = PlanCache::new();
        let classic =
            simulate_open(&dags, s2.as_mut(), &platform, &model, &config, &stream, &mut cache);

        assert_metrics_identical(&capacity, &classic, &format!("capacity-vs-classic {spec}"));
        let workers: Vec<usize> = platform.devices.iter().map(|d| d.workers).collect();
        assert_eq!(
            capacity.device_utilization(&workers),
            classic.device_utilization(&workers),
            "{spec}: utilization drifted"
        );
    }
}

// --- 3. bounded memory ----------------------------------------------

/// A 5x longer session must not move the slab/arena high-water mark:
/// completed jobs recycle their slots, so memory tracks the admission
/// window, not the job count.
#[test]
fn slab_memory_high_water_is_independent_of_session_length() {
    let stream = "stream:arrival=fixed,rate=400,queue=8";
    let short = run_capacity(500, "dmda", stream, EventQueueKind::Ladder);
    let long = run_capacity(2500, "dmda", stream, EventQueueKind::Ladder);
    assert!(short.mem_high_water_bytes > 0, "high-water mark not tracked");
    assert_eq!(
        short.mem_high_water_bytes, long.mem_high_water_bytes,
        "slab/arena memory grew with session length (slot recycling broken)"
    );
    assert_eq!(long.job_count(), 2500, "every submitted job must complete");
    assert_eq!(long.rejected_count(), 0, "under-capacity fifo session must not reject");
    assert!(long.events_processed > 2500 * 4, "event count implausibly low");
}

// --- 4. report-path regressions -------------------------------------

/// A bursty overload against a tiny admission window with a near-zero
/// wait budget rejects almost everything; the session report must stay
/// finite end to end (the all-rejected unit tests live in
/// `sim::report`; this pins the full engine path).
#[test]
fn heavily_rejecting_session_reports_finite_metrics() {
    let stream = "stream:arrival=bursty,rate=2000,burst=16,queue=1,admit=reject,budget=0.01,seed=7";
    let session = run_capacity(64, "dmda", stream, EventQueueKind::Ladder);
    assert!(session.rejected_count() > 0, "overload session should reject");
    for (name, v) in session.scalar_metrics() {
        assert!(v.is_finite(), "metric {name} is not finite: {v}");
    }
}

/// Device utilization divides by wall-clock span x workers: summing
/// `util_d * span_ms * workers_d` over devices must recover the total
/// busy time (`useful_work_ms`), pinning the denominator.
#[test]
fn device_utilization_keeps_the_span_denominator() {
    let session =
        run_capacity(200, "dmda", "stream:arrival=poisson,rate=300,queue=8", EventQueueKind::Ladder);
    let platform = Platform::paper();
    let workers: Vec<usize> = platform.devices.iter().map(|d| d.workers).collect();
    let util = session.device_utilization(&workers);
    assert_eq!(util.len(), workers.len());
    let mut recovered = 0.0;
    for (d, u) in util.iter().enumerate() {
        assert!((0.0..=1.0).contains(u), "device {d} utilization {u} out of [0, 1]");
        recovered += u * session.span_ms * workers[d] as f64;
    }
    let rel = (recovered - session.useful_work_ms).abs() / session.useful_work_ms.max(1e-12);
    assert!(
        rel < 1e-9,
        "span denominator drifted: recovered {recovered} vs busy {}",
        session.useful_work_ms
    );
}
