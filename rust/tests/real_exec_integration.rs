//! Real-execution integration: the AOT artifacts run through PJRT under
//! every scheduler with verified numerics; pinned policies' transfer
//! ledgers match the simulator exactly. Tests no-op (pass trivially)
//! when `make artifacts` has not been run.

use std::path::{Path, PathBuf};

use hetsched::coordinator::{measure_kernels, ExecEngine, ExecOptions};
use hetsched::dag::{generate_layered, workloads, GeneratorConfig, KernelKind};
use hetsched::perfmodel::{CalibratedModel, MeasuredModel, PerfModel};
use hetsched::platform::Platform;
use hetsched::runtime::{KernelRuntime, RuntimeService};
use hetsched::sched;
use hetsched::sim::{simulate, SimConfig};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn paper_task_real_vs_sim_transfer_agreement() {
    let Some(dir) = artifacts() else { return };
    let svc = RuntimeService::spawn(&dir).unwrap();
    let engine = ExecEngine::new(svc.clone(), Platform::paper());
    let model = CalibratedModel::paper();
    for kernel in [KernelKind::Ma, KernelKind::Mm] {
        let dag = generate_layered(&GeneratorConfig::paper(kernel, 64));
        for name in ["gp", "gpu-only", "cpu-only"] {
            let mut s = sched::by_name(name).unwrap();
            let real = engine.run(&dag, s.as_mut(), &model, &ExecOptions::default()).unwrap();
            let mut s = sched::by_name(name).unwrap();
            let sim = simulate(&dag, s.as_mut(), &Platform::paper(), &model, &SimConfig::default());
            assert_eq!(real.assignments, sim.assignments, "{kernel}/{name}");
            assert_eq!(real.ledger.count, sim.ledger.count, "{kernel}/{name}");
            assert_eq!(real.ledger.bytes, sim.ledger.bytes, "{kernel}/{name}");
        }
    }
    svc.shutdown();
}

#[test]
fn online_policies_verify_on_all_workloads() {
    let Some(dir) = artifacts() else { return };
    let svc = RuntimeService::spawn(&dir).unwrap();
    let engine = ExecEngine::new(svc.clone(), Platform::paper());
    let model = CalibratedModel::paper();
    let dags = [
        workloads::chain(6, KernelKind::Mm, 64),
        workloads::fork_join(8, KernelKind::Ma, 128),
        workloads::stencil(3, 3, 64),
        workloads::cholesky(3, 64),
        workloads::montage(4, 64),
    ];
    for dag in &dags {
        for name in ["eager", "dmda", "heft"] {
            let mut s = sched::by_name(name).unwrap();
            // verify=true raises on any numeric mismatch.
            engine.run(dag, s.as_mut(), &model, &ExecOptions::default()).unwrap();
        }
    }
    svc.shutdown();
}

#[test]
fn measured_model_drives_gp_plan() {
    // The paper's full offline loop: measure kernels -> weighted graph ->
    // partition -> run. With identical per-device measurements the ratio
    // is 0.5/0.5 and gp must split the work.
    let Some(dir) = artifacts() else { return };
    let rt = KernelRuntime::open(&dir).unwrap();
    let measured: MeasuredModel = measure_kernels(&rt, 2, 2).unwrap();
    let platform = Platform::paper();
    let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, 128));
    let r = measured.workload_ratios(KernelKind::Mm, 128, &platform);
    assert!((r[0] - 0.5).abs() < 1e-6, "identical measurements -> even split");
    let mut gp = sched::GraphPartition::new(sched::GpConfig::default());
    gp.plan_now(&dag, &platform, &measured);
    let cpu = gp.parts().iter().filter(|&&p| p == 0).count();
    let gpu = gp.parts().iter().filter(|&&p| p == 1).count();
    assert!(cpu > 5 && gpu > 5, "even ratio must split work: {cpu}/{gpu}");
}

#[test]
fn different_seeds_give_different_data_but_both_verify() {
    let Some(dir) = artifacts() else { return };
    let svc = RuntimeService::spawn(&dir).unwrap();
    let engine = ExecEngine::new(svc.clone(), Platform::paper());
    let model = CalibratedModel::paper();
    let dag = workloads::chain(3, KernelKind::Ma, 64);
    for seed in [1u64, 2, 3] {
        let mut s = sched::by_name("dmda").unwrap();
        let opts = ExecOptions { seed, ..Default::default() };
        engine.run(&dag, s.as_mut(), &model, &opts).unwrap();
    }
    svc.shutdown();
}
