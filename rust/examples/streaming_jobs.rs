//! Streaming multi-DAG sessions: jobs arriving over time instead of one
//! offline batch — the scenario the paper's one-shot gp decision (§IV.D)
//! cannot express.
//!
//! Four things to watch in the output:
//!
//! 1. **Plan-cache amortization** — a stream of structurally identical
//!    jobs plans once; every repeat submission is a hash lookup
//!    (`plan_ms` collapses after job 0).
//! 2. **Config-string policies** — every policy variant is a registry
//!    spec (`"gp:window=12"`), no recompilation.
//! 3. **Windowed replanning** — on the two-phase workload (MM stage
//!    feeding an MA stage), `gp:window=…` re-partitions the undispatched
//!    frontier as the first stage drains and beats one-shot gp.
//! 4. **The open system** — Poisson arrivals put several jobs in flight
//!    at once on the shared machine; the session reports sojourn
//!    percentiles, queueing delay and throughput, and cross-job
//!    windowed gp replans the *union* frontier of everything in flight.
//!
//! ```bash
//! cargo run --release --example streaming_jobs
//! ```

use hetsched::dag::{generate_layered, workloads, GeneratorConfig, KernelKind};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, Table};
use hetsched::session::SchedSession;
use hetsched::sim::StreamConfig;

fn main() {
    let platform = Platform::paper();
    println!("{}", platform.table1());

    // --- 1. identical-job stream through one session: plan once ---
    let mut session = SchedSession::from_spec(
        "gp",
        platform.clone(),
        Box::new(CalibratedModel::paper()),
    )
    .expect("spec parses");
    let mut table = Table::new(
        "stream of 8 identical MM jobs (gp, shared plan cache)",
        &["job", "makespan_ms", "plan_ms", "cache"],
    );
    for job in 0..8 {
        let dag = generate_layered(&GeneratorConfig::paper(KernelKind::Mm, 1024));
        let r = session.submit(&dag);
        table.row(vec![
            job.to_string(),
            fmt_ms(r.makespan_ms),
            format!("{:.4}", r.plan_ns as f64 / 1e6),
            if job == 0 { "miss".into() } else { "hit".to_string() },
        ]);
    }
    let report = session.finish();
    println!("{}", table.render());
    println!(
        "8 jobs, {} plan build(s); repeat-submission planning cost: {:.4} ms total\n",
        report.cache_misses,
        report.repeat_plan_ns() as f64 / 1e6
    );

    // --- 2 + 3. phased workload: one-shot gp vs windowed gp ---
    let mut table = Table::new(
        "two-phase workload (4 layers MM -> 4 layers MA, width 8, size 256)",
        &["policy", "makespan_ms", "transfers", "cpu tasks", "gpu tasks"],
    );
    for spec in ["eager", "dmda", "gp", "gp:window=12"] {
        let mut session = SchedSession::from_spec(
            spec,
            platform.clone(),
            Box::new(CalibratedModel::paper()),
        )
        .expect("spec parses");
        let dag = workloads::phased(8, 4, 256);
        let r = session.submit(&dag);
        table.row(vec![
            spec.to_string(),
            fmt_ms(r.makespan_ms),
            r.ledger.count.to_string(),
            r.tasks_per_device[0].to_string(),
            r.tasks_per_device[1].to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "windowed gp recovers the MA phase's CPU share that the one-shot ratio gives away\n"
    );

    // --- 4. open system: Poisson arrivals, concurrent in-flight jobs ---
    let stream = StreamConfig::from_spec("stream:arrival=poisson,rate=220,queue=8")
        .expect("spec parses");
    let jobs: Vec<_> = (0..24).map(|_| workloads::phased(8, 4, 256)).collect();
    let mut table = Table::new(
        "open system: 24 phased jobs, poisson @ 220 jobs/s, queue 8",
        &["policy", "p50_ms", "p95_ms", "p99_ms", "mean_qdelay_ms", "jobs/s", "max in flight"],
    );
    for spec in ["dmda", "gp", "gp:window=12"] {
        let mut session = SchedSession::from_spec(
            spec,
            platform.clone(),
            Box::new(CalibratedModel::paper()),
        )
        .expect("spec parses");
        session.submit_stream(&jobs, &stream);
        let r = session.finish();
        table.row(vec![
            spec.to_string(),
            fmt_ms(r.p50_sojourn_ms()),
            fmt_ms(r.p95_sojourn_ms()),
            fmt_ms(r.p99_sojourn_ms()),
            fmt_ms(r.mean_queueing_delay_ms()),
            format!("{:.1}", r.throughput_jps()),
            r.max_concurrent_jobs().to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "under load, cross-job windowed gp rebalances the union frontier of every in-flight job"
    );
}
