//! Montage-style astronomy mosaic workflow — the workload class Tanaka &
//! Tatebe's multi-constraint partitioning paper (the paper's related
//! work [11]) targets. Sweeps mosaic width and compares all policies on
//! makespan and data movement; writes the partitioned DOT for the widest
//! case.
//!
//! ```bash
//! cargo run --release --example montage_workflow
//! ```

use hetsched::dag::{dot, workloads};
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, Table};
use hetsched::sched::{self, GpConfig, GraphPartition};
use hetsched::sim::{simulate, SimConfig};

fn main() {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    println!("{}", platform.table1());

    let size = 1024u32;
    let mut table = Table::new(
        format!("Montage workflow, tile size {size}"),
        &["width", "nodes", "edges", "policy", "makespan_ms", "transfers", "MB_moved"],
    );
    for width in [4usize, 8, 16, 32] {
        let dag = workloads::montage(width, size);
        for name in ["eager", "dmda", "gp", "heft"] {
            let mut s = sched::by_name(name).unwrap();
            let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
            table.row(vec![
                width.to_string(),
                dag.node_count().to_string(),
                dag.edge_count().to_string(),
                name.to_string(),
                fmt_ms(r.makespan_ms),
                r.ledger.count.to_string(),
                format!("{:.1}", r.ledger.bytes as f64 / 1e6),
            ]);
        }
    }
    println!("{}", table.render());

    // Partition the widest mosaic and dump the colored DOT.
    let dag = workloads::montage(32, size);
    let mut gp = GraphPartition::new(GpConfig::default());
    gp.plan_now(&dag, &platform, &model);
    let result = gp.last_result().unwrap();
    println!(
        "width-32 partition: edge-cut={} us, weights={:?}, R=({:.3}, {:.3})",
        result.edge_cut,
        result.part_weights,
        gp.ratios()[0],
        gp.ratios()[1]
    );
    let out = dot::write(&dag, "montage32", Some(gp.parts()));
    let path = std::env::temp_dir().join("montage32_partitioned.dot");
    if std::fs::write(&path, out).is_ok() {
        println!("partitioned DOT written to {}", path.display());
    }
}
