//! Quickstart: express a task DAG, partition it with the paper's policy,
//! and run it on the simulated CPU+GPU platform.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hetsched::dag::{dot, Dag, KernelKind};
use hetsched::metrics;
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::sched::{self, GpConfig, GraphPartition};
use hetsched::sim::{simulate, SimConfig};

fn main() {
    // 1. Express the task graph — the same thing the paper's DOT files
    //    do. Here: a small two-stage pipeline of matrix kernels.
    let mut dag = Dag::new();
    let a = dag.add_node("load_a", KernelKind::Ma, 1024);
    let b = dag.add_node("load_b", KernelKind::Ma, 1024);
    let m1 = dag.add_node("gemm_1", KernelKind::Mm, 1024);
    let m2 = dag.add_node("gemm_2", KernelKind::Mm, 1024);
    let sum = dag.add_node("combine", KernelKind::Ma, 1024);
    dag.add_edge(a, m1);
    dag.add_edge(b, m1);
    dag.add_edge(a, m2);
    dag.add_edge(b, m2);
    dag.add_edge(m1, sum);
    dag.add_edge(m2, sum);

    // ... or parse it from DOT:
    let parsed = dot::parse(
        "digraph g { x [kernel=mm, size=512]; y [kernel=ma, size=512]; x -> y; }",
        512,
    )
    .expect("dot parses");
    println!("parsed DOT graph with {} nodes\n", parsed.dag.node_count());

    // 2. The platform: the paper's i7-4770 + GTX TITAN over PCIe 3.0.
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    println!("{}", platform.table1());

    // 3. Offline graph-partition plan (Formula (1) ratios -> multilevel
    //    partition -> pin).
    let mut gp = GraphPartition::new(GpConfig::default());
    gp.plan_now(&dag, &platform, &model);
    println!(
        "workload ratios (Formula 1): R_cpu={:.3} R_gpu={:.3}",
        gp.ratios()[0],
        gp.ratios()[1]
    );
    for (id, node) in dag.nodes() {
        println!("  {:<10} -> {}", node.name, platform.devices[gp.parts()[id]].name);
    }

    // 4. Run under all three of the paper's policies and compare.
    println!();
    for name in ["eager", "dmda", "gp"] {
        let mut s = sched::by_name(name).unwrap();
        let report = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
        println!("{}", metrics::summary_line(&report));
    }

    // 5. Visualize: partitioned DOT (open with graphviz).
    let colored = dot::write(&dag, "quickstart", Some(gp.parts()));
    println!("\npartitioned DOT:\n{colored}");
}
