//! Future-work extension (paper §VI): "extend this policy to more
//! heterogeneous systems, such as systems equipped with a CPU, a GPU, and
//! an FPGA." The k-way recursive-bisection partitioner makes this a
//! config change: three target ratios from the generalized Formula (1),
//! k = 3 parts, pins per device.
//!
//! ```bash
//! cargo run --release --example tri_device
//! ```

use hetsched::dag::{generate_layered, GeneratorConfig, KernelKind};
use hetsched::perfmodel::{CalibratedModel, PerfModel};
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, Table};
use hetsched::sched::{self, GpConfig, GraphPartition};
use hetsched::sim::{simulate, SimConfig};

fn main() {
    let platform = Platform::tri_device();
    let model = CalibratedModel::tri_device();
    println!("{}", platform.table1());

    for (kernel, label) in [(KernelKind::Ma, "MA"), (KernelKind::Mm, "MM")] {
        let mut table = Table::new(
            format!("CPU+GPU+FPGA, {label} kernels, 200-kernel task"),
            &["size", "policy", "makespan_ms", "transfers", "cpu", "gpu", "fpga"],
        );
        for &n in &[512u32, 1024, 2048] {
            let dag = generate_layered(&GeneratorConfig::scaled(200, kernel, n, 17));
            for name in ["eager", "dmda", "gp"] {
                let mut s = sched::by_name(name).unwrap();
                let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
                table.row(vec![
                    n.to_string(),
                    name.to_string(),
                    fmt_ms(r.makespan_ms),
                    r.ledger.count.to_string(),
                    r.tasks_per_device[0].to_string(),
                    r.tasks_per_device[1].to_string(),
                    r.tasks_per_device[2].to_string(),
                ]);
            }
        }
        println!("{}", table.render());
    }

    // Show the generalized Formula (1) targets and achieved split.
    let dag = generate_layered(&GeneratorConfig::scaled(200, KernelKind::Ma, 2048, 17));
    let mut gp = GraphPartition::new(GpConfig::default());
    gp.plan_now(&dag, &platform, &model);
    println!("generalized Formula (1) targets: {:?}", gp.ratios());
    println!(
        "achieved part weights: {:?} (edge cut {} us)",
        gp.last_result().unwrap().part_weights,
        gp.last_result().unwrap().edge_cut
    );
    for d in 0..3 {
        let t = model.kernel_time_ms(KernelKind::Ma, 2048, d);
        println!("  device {d} ({}) MA@2048: {t:.3} ms", platform.devices[d].name);
    }
}
