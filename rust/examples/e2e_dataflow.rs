//! End-to-end driver (the EXPERIMENTS.md validation run): proves all
//! three layers compose on a real workload.
//!
//! Pipeline exercised:
//!   Pallas kernels (L1, python) -> jax model (L2) -> AOT HLO text
//!   -> PJRT CPU runtime (rust) -> MSI data layer -> schedulers
//!   -> threaded coordinator -> verified numerics.
//!
//! Runs the paper's 38-kernel / 75-edge task with real compiled kernels
//! under all three policies, verifies every kernel output against the
//! pure-Rust oracle, then cross-checks transfer counts against the
//! discrete-event simulator and reports measured kernel times.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_dataflow
//! ```

use std::path::Path;

use hetsched::coordinator::{measure_kernels, ExecEngine, ExecOptions};
use hetsched::dag::{generate_layered, GeneratorConfig, KernelKind};
use hetsched::metrics;
use hetsched::perfmodel::{CalibratedModel, PerfModel};
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, Table};
use hetsched::runtime::{KernelRuntime, RuntimeService};
use hetsched::sched;
use hetsched::sim::{simulate, SimConfig};

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }

    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    println!("{}", platform.table1());

    // --- offline measurement (the paper's method for node weights) ---
    let rt_local = KernelRuntime::open(&dir)?;
    println!("PJRT platform: {}\n", rt_local.platform_name());
    let measured = measure_kernels(&rt_local, 1, 3)?;
    let mut mt = Table::new("measured PJRT kernel times (3 reps)", &["op", "n", "ms"]);
    for a in &rt_local.manifest().entries {
        mt.row(vec![
            a.op.to_string(),
            a.n.to_string(),
            fmt_ms(measured.kernel_time_ms(a.op, a.n, 0)),
        ]);
    }
    println!("{}", mt.render());
    drop(rt_local);

    // --- real execution of the paper task, all three policies ---
    let svc = RuntimeService::spawn(&dir)?;
    let engine = ExecEngine::new(svc.clone(), platform.clone());

    for (kernel, n) in [(KernelKind::Mm, 128u32), (KernelKind::Ma, 256u32)] {
        println!("== real run: 38-kernel task, {kernel} kernels at {n} ==");
        let dag = generate_layered(&GeneratorConfig::paper(kernel, n));
        let mut rows = Table::new(
            format!("real PJRT execution ({kernel} @ {n}, verified)"),
            &["policy", "makespan_ms", "transfers", "bytes", "cpu_tasks", "gpu_tasks"],
        );
        for name in ["eager", "dmda", "gp"] {
            let mut s = sched::by_name(name).unwrap();
            let opts = ExecOptions::default(); // verify = true
            let r = engine.run(&dag, s.as_mut(), &model, &opts)?;
            rows.row(vec![
                name.to_string(),
                fmt_ms(r.makespan_ms),
                r.ledger.count.to_string(),
                r.ledger.bytes.to_string(),
                r.tasks_per_device[0].to_string(),
                r.tasks_per_device[1].to_string(),
            ]);
            println!("  {}", metrics::summary_line(&r));

            // Cross-check offline policies against the simulator: pinned
            // schedules must produce identical transfer ledgers.
            if name == "gp" {
                let mut s2 = sched::by_name(name).unwrap();
                let sim =
                    simulate(&dag, s2.as_mut(), &platform, &model, &SimConfig::default());
                assert_eq!(
                    r.ledger.count, sim.ledger.count,
                    "gp transfer counts must match sim exactly"
                );
                println!("  gp transfer ledger matches simulator ({} transfers)", sim.ledger.count);
            }
        }
        println!("{}", rows.render());
    }

    svc.shutdown();
    println!("e2e OK: all kernels verified against the oracle; all layers compose.");
    Ok(())
}
