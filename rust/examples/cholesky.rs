//! Tiled Cholesky factorization — the dense-linear-algebra DAG the
//! paper's related work motivates (Ltaief et al., LAWN 223). POTRF/TRSM
//! tiles run as `mm` kernels, SYRK/GEMM updates as fused `mm_add`.
//!
//! Compares scheduling policies over tile-grid sizes in the simulator,
//! then (if artifacts are built) executes a small instance for real with
//! verified numerics.
//!
//! ```bash
//! cargo run --release --example cholesky
//! ```

use std::path::Path;

use hetsched::coordinator::{ExecEngine, ExecOptions};
use hetsched::dag::workloads;
use hetsched::perfmodel::CalibratedModel;
use hetsched::platform::Platform;
use hetsched::report::{fmt_ms, Table};
use hetsched::runtime::RuntimeService;
use hetsched::sched;
use hetsched::sim::{simulate, SimConfig};

fn main() -> anyhow::Result<()> {
    let platform = Platform::paper();
    let model = CalibratedModel::paper();
    println!("{}", platform.table1());

    let tile = 1024u32;
    let mut table = Table::new(
        format!("tiled Cholesky, tile size {tile}"),
        &["tiles", "nodes", "policy", "makespan_ms", "transfers", "cpu_tasks", "gpu_tasks"],
    );
    for t in [3usize, 5, 8, 12] {
        let dag = workloads::cholesky(t, tile);
        for name in ["eager", "dmda", "gp"] {
            let mut s = sched::by_name(name).unwrap();
            let r = simulate(&dag, s.as_mut(), &platform, &model, &SimConfig::default());
            table.row(vec![
                format!("{t}x{t}"),
                dag.node_count().to_string(),
                name.to_string(),
                fmt_ms(r.makespan_ms),
                r.ledger.count.to_string(),
                r.tasks_per_device[0].to_string(),
                r.tasks_per_device[1].to_string(),
            ]);
        }
    }
    println!("{}", table.render());

    // Real execution of a 4x4 tile grid at size 64 (if artifacts exist).
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let svc = RuntimeService::spawn(&dir)?;
        let engine = ExecEngine::new(svc.clone(), platform.clone());
        let dag = workloads::cholesky(4, 64);
        let mut s = sched::by_name("gp").unwrap();
        let r = engine.run(&dag, s.as_mut(), &model, &ExecOptions::default())?;
        println!(
            "real 4x4 Cholesky (tile 64): {} tasks verified, makespan {:.2} ms, {} transfers",
            r.assignments.len(),
            r.makespan_ms,
            r.ledger.count
        );
        svc.shutdown();
    } else {
        println!("(skip real run: artifacts missing — `make artifacts`)");
    }
    Ok(())
}
