//! Minimal offline-vendored subset of the `anyhow` API.
//!
//! The real crates.io `anyhow` is unavailable in this offline build (the
//! repo policy is no network dependencies — cf. the in-tree JSON parser
//! and PCG RNG). This shim supplies the slice of the API the codebase
//! uses: `Result`/`Error`, the `anyhow!`/`bail!`/`ensure!` macros, and
//! the `Context` extension trait on `Result` and `Option`. Errors are
//! flattened message strings; context prepends `"{context}: "` like
//! anyhow's single-line `{:#}` rendering.

use std::fmt;

/// A flattened, message-carrying error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes the blanket conversion below coherent (same trick
// as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — result with a flattened error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `$cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond))
        }
    };
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::other("disk on fire"))?;
        Ok(())
    }

    #[test]
    fn from_std_error_via_question_mark() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through {x}"))
        }
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("right out"));
        assert!(f(1).unwrap_err().to_string().contains("fell through 1"));
    }

    #[test]
    fn double_question_mark_nesting() {
        fn inner() -> Result<Result<u32>> {
            Ok(Ok(7))
        }
        fn outer() -> Result<u32> {
            let v = inner().context("recv")??;
            Ok(v)
        }
        assert_eq!(outer().unwrap(), 7);
    }
}
