"""L2 shape/semantics tests for the model-layer ops."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import matadd_ref, matmul_ref, mm_add_ref


def _rand(shape, seed):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


@pytest.mark.parametrize("op", list(model.OPS))
def test_ops_shapes_and_arity(op):
    fn, arity = model.OPS[op]
    n = 32
    args = [_rand((n, n), i) for i in range(arity)]
    out = fn(*args)
    assert out.shape == (n, n)
    assert out.dtype == jnp.float32


def test_mm_add_matches_ref():
    a, b, c = (_rand((48, 48), i) for i in range(3))
    np.testing.assert_allclose(model.mm_add(a, b, c), mm_add_ref(a, b, c),
                               rtol=1e-5, atol=1e-4)


def test_ma_chain_matches_ref():
    x, y, z = (_rand((48, 48), 10 + i) for i in range(3))
    np.testing.assert_allclose(model.ma_chain(x, y, z),
                               matadd_ref(matadd_ref(x, y), z), rtol=1e-6)


def test_example_args_match_arity():
    for op, (_, arity) in model.OPS.items():
        specs = model.example_args(op, 16)
        assert len(specs) == arity
        assert all(s.shape == (16, 16) for s in specs)


def test_flops_monotone_in_size():
    for op in model.OPS:
        assert model.flops(op, 128) > model.flops(op, 64)


def test_mm_flops_cubic():
    assert model.flops("mm", 64) == 2 * 64**3
    assert model.flops("ma", 64) == 64 * 64


def test_io_bytes():
    # ma: 2 inputs + 1 output, f32.
    assert model.io_bytes("ma", 64) == 3 * 64 * 64 * 4
    assert model.io_bytes("mm_add", 64) == 4 * 64 * 64 * 4
