"""AOT pipeline tests: HLO text artifacts + manifest integrity.

The HLO-text interchange (not serialized protos) is load-bearing — see
aot.py's module docstring. These tests re-lower a small op, check the text
parses back through xla_client, and validate the manifest schema the Rust
runtime consumes.
"""

import json
import os

import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_op_emits_hlo_text():
    text = aot.lower_op("ma", 16)
    assert "HloModule" in text
    assert "f32[16,16]" in text


def test_lowered_mm_contains_dot():
    text = aot.lower_op("mm", 16)
    assert "dot(" in text or "dot " in text


def test_build_roundtrip(tmp_path):
    manifest = aot.build(str(tmp_path), ops=["ma"], sizes=[8, 16])
    assert len(manifest["entries"]) == 2
    for e in manifest["entries"]:
        p = tmp_path / e["path"]
        assert p.exists()
        assert "HloModule" in p.read_text()
    m2 = json.loads((tmp_path / "manifest.json").read_text())
    assert m2 == manifest


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_shipped_manifest_schema():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    assert m["interchange"] == "hlo-text"
    names = set()
    for e in m["entries"]:
        assert set(e) >= {"name", "op", "n", "arity", "path", "flops",
                          "io_bytes", "vmem_bytes_per_step"}
        assert e["name"] not in names
        names.add(e["name"])
        assert os.path.exists(os.path.join(ART, e["path"]))
        assert e["arity"] == model.OPS[e["op"]][1]
        assert e["flops"] == model.flops(e["op"], e["n"])


def test_vmem_estimate_positive():
    for op in ("ma", "mm", "mm_add"):
        assert aot.vmem_estimate(op, 128) > 0
