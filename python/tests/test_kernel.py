"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts — the same
jitted functions tested here are the ones aot.py lowers to HLO text.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matadd, matmul
from compile.kernels.matadd import _largest_divisor_leq as add_div
from compile.kernels.matmul import (
    mxu_utilization_estimate,
    pick_blocks,
    vmem_bytes_per_step,
)
from compile.kernels.ref import matadd_ref, matmul_ref, mm_add_ref

SIZES = [8, 16, 64, 128, 256, 384]


def _rand(shape, seed, dtype=np.float32):
    return np.random.RandomState(seed).randn(*shape).astype(dtype)


# ---------------------------------------------------------------- matmul

@pytest.mark.parametrize("n", SIZES)
def test_matmul_matches_ref_square(n):
    x, y = _rand((n, n), 0), _rand((n, n), 1)
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(8, 16, 24), (128, 64, 32), (256, 128, 8),
                                    (16, 256, 16), (120, 72, 48)])
def test_matmul_rectangular(m, k, n):
    x, y = _rand((m, k), 2), _rand((k, n), 3)
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y),
                               rtol=1e-5, atol=1e-4)


def test_matmul_identity():
    x = _rand((64, 64), 4)
    eye = np.eye(64, dtype=np.float32)
    np.testing.assert_allclose(matmul(x, eye), x, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(matmul(eye, x), x, rtol=1e-6, atol=1e-6)


def test_matmul_zeros():
    x = _rand((32, 32), 5)
    z = np.zeros((32, 32), np.float32)
    assert np.abs(np.asarray(matmul(x, z))).max() == 0.0


def test_matmul_bfloat16_inputs_fp32_accumulation():
    x = _rand((128, 128), 6).astype(jnp.bfloat16)
    y = _rand((128, 128), 7).astype(jnp.bfloat16)
    got = matmul(x, y)
    assert got.dtype == jnp.bfloat16
    want = matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-1)


def test_matmul_nondivisible_by_mxu_edge():
    # 129 is coprime with 128: blocks shrink to divisors; still correct.
    x, y = _rand((129, 129), 8), _rand((129, 129), 9)
    np.testing.assert_allclose(matmul(x, y, block_cap=64), matmul_ref(x, y),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 16, 24, 32, 48, 64]),
    k=st.sampled_from([8, 16, 24, 32, 48, 64]),
    n=st.sampled_from([8, 16, 24, 32, 48, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    x, y = _rand((m, k), seed % 1000), _rand((k, n), seed % 1000 + 1)
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(1e-3, 1e3), n=st.sampled_from([16, 32, 64]))
def test_matmul_scale_invariance(scale, n):
    # (s*x) @ y == s * (x @ y): catches accumulation-order bugs at range.
    x, y = _rand((n, n), 10), _rand((n, n), 11)
    a = np.asarray(matmul((scale * x).astype(np.float32), y), np.float64)
    b = scale * np.asarray(matmul(x, y), np.float64)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3 * scale)


# ---------------------------------------------------------------- matadd

@pytest.mark.parametrize("n", SIZES)
def test_matadd_matches_ref(n):
    x, y = _rand((n, n), 20), _rand((n, n), 21)
    np.testing.assert_allclose(matadd(x, y), matadd_ref(x, y), rtol=1e-6)


@pytest.mark.parametrize("m,n", [(8, 24), (256, 8), (1, 128), (300, 7)])
def test_matadd_rectangular(m, n):
    x, y = _rand((m, n), 22), _rand((m, n), 23)
    np.testing.assert_allclose(matadd(x, y), matadd_ref(x, y), rtol=1e-6)


def test_matadd_commutative():
    x, y = _rand((64, 64), 24), _rand((64, 64), 25)
    np.testing.assert_allclose(matadd(x, y), matadd(y, x), rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matadd_hypothesis_arbitrary_shapes(m, n, seed):
    x, y = _rand((m, n), seed % 997), _rand((m, n), seed % 997 + 1)
    np.testing.assert_allclose(matadd(x, y), matadd_ref(x, y), rtol=1e-6)


# ------------------------------------------------------- structural/§Perf

def test_pick_blocks_divide_problem():
    for (m, k, n) in [(64, 64, 64), (384, 384, 384), (129, 77, 500)]:
        bm, bk, bn = pick_blocks(m, k, n)
        assert m % bm == 0 and k % bk == 0 and n % bn == 0
        assert bm <= 128 and bk <= 128 and bn <= 128


def test_vmem_budget_under_16mib():
    # Largest AOT'd size must keep per-step VMEM well under a TPU core's
    # ~16 MiB (DESIGN.md §Perf L1 target).
    assert vmem_bytes_per_step(512, 512, 512) < 16 * 2**20 // 4


def test_mxu_fill_full_at_mxu_multiples():
    assert mxu_utilization_estimate(512, 512, 512) == 1.0
    assert mxu_utilization_estimate(64, 64, 64) < 1.0


def test_add_divisor_helper():
    assert add_div(256, 256) == 256
    assert add_div(300, 256) == 150
    assert add_div(7, 256) == 7
    assert add_div(97, 64) == 1
