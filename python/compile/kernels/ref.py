"""Pure-jnp oracles for the Pallas kernels — the L1 correctness signal.

Every kernel in this package must match its oracle to float tolerance
across the shape/dtype sweep in python/tests/test_kernel.py.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Reference mm: plain jnp.matmul with fp32 accumulation."""
    out = jnp.matmul(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(x.dtype)


def matadd_ref(x, y):
    """Reference ma: plain elementwise add."""
    return x + y


def mm_add_ref(a, b, c):
    """Reference fused task kernel: a @ b + c."""
    return matadd_ref(matmul_ref(a, b), c)
