"""Blocked matrix-multiplication Pallas kernel (the paper's MM kernel).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's MM kernel
is CUBLAS on a GTX TITAN. Instead of porting a threadblock decomposition,
this kernel is written TPU-style:

* the grid iterates over (M/bm, N/bn) output tiles with an inner K-block
  reduction axis — each grid step feeds one `bm x bk @ bk x bn` MXU-shaped
  matmul;
* `BlockSpec`s express the HBM->VMEM staging schedule (one A-row-panel and
  one B-col-panel resident per step);
* the fp32 accumulator lives in the revisited output tile (innermost grid
  axis), the standard Pallas accumulation idiom;
* block sizes default to 128 (the MXU systolic-array edge) and shrink to
  the largest divisor of the problem size when it is smaller or not
  divisible, so the kernel stays correct for every shape the test suite
  throws at it.

The kernel must be lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic array edge; the natural tile for fp32/bf16 matmul on TPU.
MXU_EDGE = 128


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1)."""
    d = min(n, cap)
    while n % d != 0:
        d -= 1
    return d


def pick_blocks(m: int, k: int, n: int, cap: int = MXU_EDGE):
    """Choose (bm, bk, bn) tile sizes for an ``m x k @ k x n`` product."""
    return (
        _largest_divisor_leq(m, cap),
        _largest_divisor_leq(k, cap),
        _largest_divisor_leq(n, cap),
    )


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One grid step: accumulate ``x_tile @ y_tile`` into the output tile.

    The K axis is the innermost grid dimension, so the same output tile is
    revisited ``nk`` times; it is zeroed on the first visit and accumulated
    into afterwards (fp32 accumulation regardless of input dtype).
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_cap",))
def matmul(x: jax.Array, y: jax.Array, *, block_cap: int = MXU_EDGE) -> jax.Array:
    """``x @ y`` via a blocked Pallas kernel (fp32 accumulation).

    ``x``: (m, k), ``y``: (k, n) -> (m, n). Output dtype follows ``x``.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm, bk, bn = pick_blocks(m, k, n, block_cap)
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            # A row-panel: tile (bm, bk) at block-index (i, kk).
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # B col-panel: tile (bk, bn) at block-index (kk, j).
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, y)


def vmem_bytes_per_step(m: int, k: int, n: int, dtype_bytes: int = 4,
                        block_cap: int = MXU_EDGE) -> int:
    """Estimated VMEM residency per grid step (A tile + B tile + O tile).

    Used by the §Perf analysis: must stay well under the ~16 MiB VMEM of a
    TPU core for the chosen block sizes.
    """
    bm, bk, bn = pick_blocks(m, k, n, block_cap)
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(m: int, k: int, n: int,
                             block_cap: int = MXU_EDGE) -> float:
    """Fraction of each MXU pass doing useful work (tile fill ratio)."""
    bm, bk, bn = pick_blocks(m, k, n, block_cap)
    fill = lambda b: b / (((b + MXU_EDGE - 1) // MXU_EDGE) * MXU_EDGE)
    return fill(bm) * fill(bk) * fill(bn)
