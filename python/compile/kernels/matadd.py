"""Tiled elementwise matrix-addition Pallas kernel (the paper's MA kernel).

MA is bandwidth-bound on every device (paper §IV.B, Fig 4: its GPU-compute
to PCIe-transfer ratio is < 1), so the kernel is shaped for the VPU rather
than the MXU: the grid walks row panels, each step streams one
``(bm, n)`` tile of each operand through VMEM and writes the sum back.
Lane-dimension (last axis) stays whole to keep 8x128 VPU lanes full.

interpret=True for the same reason as matmul.py (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-panel height: 8 sublanes x a healthy multiple.
ROW_PANEL = 256


def _largest_divisor_leq(n: int, cap: int) -> int:
    d = min(n, cap)
    while n % d != 0:
        d -= 1
    return d


def _matadd_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=("panel_cap",))
def matadd(x: jax.Array, y: jax.Array, *, panel_cap: int = ROW_PANEL) -> jax.Array:
    """``x + y`` via a row-panel Pallas kernel. Shapes must match."""
    assert x.shape == y.shape, f"shape mismatch: {x.shape} vs {y.shape}"
    m, n = x.shape
    bm = _largest_divisor_leq(m, panel_cap)
    grid = (m // bm,)

    return pl.pallas_call(
        _matadd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.result_type(x.dtype, y.dtype)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, y)


def vmem_bytes_per_step(m: int, n: int, dtype_bytes: int = 4,
                        panel_cap: int = ROW_PANEL) -> int:
    """VMEM residency per grid step (two input tiles + one output tile)."""
    bm = _largest_divisor_leq(m, panel_cap)
    return 3 * dtype_bytes * bm * n
