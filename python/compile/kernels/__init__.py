# L1 Pallas kernels (build-time only; lowered AOT into HLO text).
from .matadd import matadd
from .matmul import matmul

__all__ = ["matadd", "matmul"]
