"""AOT driver: lower every (op, size) pair once to HLO *text* and write a
manifest the Rust runtime consumes.

HLO text — not `lowered.compile().serialize()` nor a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's bundled xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels.matadd import vmem_bytes_per_step as matadd_vmem
from .kernels.matmul import mxu_utilization_estimate
from .kernels.matmul import vmem_bytes_per_step as matmul_vmem

#: Sizes shipped as artifacts. The figure sweeps (64..2048) run on the
#: calibrated simulator; real-compute execution (examples/e2e_dataflow,
#: integration tests) uses these modest sizes so `make artifacts` stays
#: fast while still exercising multi-tile grids (256, 384 > one 128 block;
#: 384 also covers the non-power-of-two path).
DEFAULT_SIZES = (64, 128, 256, 384, 512)
DEFAULT_OPS = ("ma", "mm", "mm_add")


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XlaComputation -> HLO text (return_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_op(op: str, n: int) -> str:
    fn, _ = model.OPS[op]
    return to_hlo_text(jax.jit(fn).lower(*model.example_args(op, n)))


def vmem_estimate(op: str, n: int) -> int:
    """Structural VMEM-per-grid-step estimate recorded in the manifest
    (the §Perf L1 budget; interpret-mode wallclock is not a TPU proxy)."""
    if op in ("mm", "mm_add"):
        return matmul_vmem(n, n, n)
    return matadd_vmem(n, n)


def build(out_dir: str, ops, sizes) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for op in ops:
        _, arity = model.OPS[op]
        for n in sizes:
            name = f"{op}_{n}"
            path = f"{name}.hlo.txt"
            text = lower_op(op, n)
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(text)
            entries.append({
                "name": name,
                "op": op,
                "n": n,
                "arity": arity,
                "path": path,
                "flops": model.flops(op, n),
                "io_bytes": model.io_bytes(op, n),
                "vmem_bytes_per_step": vmem_estimate(op, n),
                "mxu_fill": (mxu_utilization_estimate(n, n, n)
                              if op in ("mm", "mm_add") else 0.0),
            })
            print(f"  wrote {path} ({len(text)} chars)")
    manifest = {
        "format": 1,
        "dtype": "f32",
        "interchange": "hlo-text",
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(entries)} entries)")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--sizes", type=int, nargs="*", default=list(DEFAULT_SIZES))
    p.add_argument("--ops", nargs="*", default=list(DEFAULT_OPS))
    args = p.parse_args()
    build(args.out_dir, args.ops, args.sizes)


if __name__ == "__main__":
    main()
