"""L2: the JAX compute graph for each task-kernel variant.

The paper's workload is a DAG whose nodes are all the same kernel type
(matrix addition or matrix multiplication, two inputs -> one output,
square fp32 matrices). Each node's compute is one of the functions below,
calling the L1 Pallas kernels; `aot.py` lowers every (op, size) pair once
to HLO text, and the Rust runtime executes those artifacts on its PJRT CPU
client. Python never runs on the execution path.
"""

import jax
import jax.numpy as jnp

from .kernels import matadd, matmul


def ma(x, y):
    """Paper's MA node: elementwise addition of two square matrices."""
    return matadd(x, y)


def mm(x, y):
    """Paper's MM node: matrix product of two square matrices."""
    return matmul(x, y)


def mm_add(a, b, c):
    """Fused task node: a @ b + c (used by the Cholesky/GEMM-chain
    examples; one HLO, one kernel launch on the device)."""
    return matadd(matmul(a, b), c)


def ma_chain(x, y, z):
    """Two dependent MA nodes fused: (x + y) + z."""
    return matadd(matadd(x, y), z)


#: op name -> (callable, arity). The AOT driver and the Rust manifest
#: loader agree on these names.
OPS = {
    "ma": (ma, 2),
    "mm": (mm, 2),
    "mm_add": (mm_add, 3),
    "ma_chain": (ma_chain, 3),
}


def example_args(op: str, n: int, dtype=jnp.float32):
    """ShapeDtypeStructs for lowering `op` at square size `n`."""
    _, arity = OPS[op]
    spec = jax.ShapeDtypeStruct((n, n), dtype)
    return (spec,) * arity


def flops(op: str, n: int) -> int:
    """Nominal flop count of one node (used by the perf model docs)."""
    if op == "ma":
        return n * n
    if op == "mm":
        return 2 * n * n * n
    if op == "mm_add":
        return 2 * n * n * n + n * n
    if op == "ma_chain":
        return 2 * n * n
    raise KeyError(op)


def io_bytes(op: str, n: int, dtype_bytes: int = 4) -> int:
    """Bytes moved across PCIe if every operand + result crosses the bus."""
    _, arity = OPS[op]
    return (arity + 1) * n * n * dtype_bytes
