# Make `import compile...` work regardless of pytest invocation directory
# (the canonical validation command runs `pytest python/tests/` from the
# repository root).
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
