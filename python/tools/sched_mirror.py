"""Python mirror of the Rust scheduling stack (model + generator + sim).

Extends ``partition_mirror`` (the bit-exact PCG32 + multilevel
partitioner transliteration from PR 1) with line-for-line mirrors of:

* ``perfmodel::CalibratedModel`` (f64 op order preserved — ``powi(3)``
  becomes ``(x*x)*x`` exactly as LLVM expands it);
* ``dag::generator::generate_layered`` and ``dag::workloads`` (phased,
  chain);
* ``sched``: eager / dmda / gp / windowed-gp policies, Formula (1)/(2)
  ratios, the µs node/edge weighting of the gp plan;
* ``sim::engine::simulate`` (ready-heap order, MSI directory, bus
  channels, prefetch, return-to-host) — transfer *counts* are exact
  integers; makespans are f64s that match the Rust engine to the last
  bit when the transliteration is faithful, and goldens derived from
  here are compared with 1e-9 relative tolerance on the Rust side.

Used to validate behavior-dependent test assertions and to generate the
golden no-drift numbers + mirror-harness ``BENCH_sched_session.json``
in environments without a Rust toolchain.

Run:  python3 python/tools/sched_mirror.py checks   # assertion sweep
      python3 python/tools/sched_mirror.py golden   # golden test values
      python3 python/tools/sched_mirror.py bench    # session bench json
      python3 python/tools/sched_mirror.py tune     # gp-window sweep
"""

import heapq
import math
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import partition_mirror as pm  # noqa: E402

MA, MM, MMADD, SOURCE = "ma", "mm", "mm_add", "source"
ARITY = {MA: 2, MM: 2, MMADD: 3, SOURCE: 0}

EFF_SIZES = [64, 128, 256, 384, 512, 768, 1024, 1280, 1536, 1792, 2048]
GPU_MM_EFF = [0.008, 0.040, 0.100, 0.240, 0.260, 0.340, 0.420, 0.480, 0.520, 0.550, 0.680]


class CalibratedModel:
    """Mirror of perfmodel::CalibratedModel (paper / tri_device)."""

    def __init__(self, tri=False):
        self.cpu_mm_gflops = 20.0
        self.cpu_ma_bw_gbs = 8.0
        self.cpu_launch_ms = 0.020
        self.gpu_peak_gflops = 4700.0
        self.gpu_ma_bw_gbs = 90.0
        self.gpu_launch_mm_ms = 0.080
        self.gpu_launch_ma_ms = 0.050
        self.fpga_mm_gflops = 500.0
        self.fpga_ma_bw_gbs = 25.0
        self.fpga_launch_ms = 0.100
        self.bus_bandwidth_gbs = 12.5
        self.bus_latency_ms = 0.020
        self.device_kinds = ["cpu", "gpu", "fpga"] if tri else ["cpu", "gpu"]

    def gpu_mm_eff(self, n):
        sizes = EFF_SIZES
        if n <= sizes[0]:
            return GPU_MM_EFF[0]
        if n >= sizes[-1]:
            return GPU_MM_EFF[-1]
        idx = next(i for i, s in enumerate(sizes) if s >= n)
        s0, s1 = float(sizes[idx - 1]), float(sizes[idx])
        e0, e1 = GPU_MM_EFF[idx - 1], GPU_MM_EFF[idx]
        t = (float(n) - s0) / (s1 - s0)
        return e0 + t * (e1 - e0)

    @staticmethod
    def _ma_time(n, bw_gbs, launch):
        fb = 3.0 * 4.0 * float(n) * float(n)
        return launch + fb / (bw_gbs * 1e9) * 1e3

    @staticmethod
    def _mm_time(n, gflops, launch):
        x = float(n)
        flops = 2.0 * ((x * x) * x)  # f64::powi(3) expands to (x*x)*x
        return launch + flops / (gflops * 1e9) * 1e3

    def kernel_time_ms(self, kernel, n, device):
        if kernel == SOURCE:
            return 0.0
        kind = self.device_kinds[device]
        if kind == "cpu":
            if kernel == MA:
                return self._ma_time(n, self.cpu_ma_bw_gbs, self.cpu_launch_ms)
            if kernel == MM:
                return self._mm_time(n, self.cpu_mm_gflops, self.cpu_launch_ms)
            if kernel == MMADD:
                return self._mm_time(n, self.cpu_mm_gflops, self.cpu_launch_ms) + self._ma_time(
                    n, self.cpu_ma_bw_gbs, 0.0
                )
        elif kind == "gpu":
            if kernel == MA:
                return self._ma_time(n, self.gpu_ma_bw_gbs, self.gpu_launch_ma_ms)
            if kernel == MM:
                return self._mm_time(
                    n, self.gpu_peak_gflops * self.gpu_mm_eff(n), self.gpu_launch_mm_ms
                )
            if kernel == MMADD:
                return self._mm_time(
                    n, self.gpu_peak_gflops * self.gpu_mm_eff(n), self.gpu_launch_mm_ms
                ) + self._ma_time(n, self.gpu_ma_bw_gbs, 0.0)
        elif kind == "fpga":
            if kernel == MA:
                return self._ma_time(n, self.fpga_ma_bw_gbs, self.fpga_launch_ms)
            if kernel == MM:
                return self._mm_time(n, self.fpga_mm_gflops, self.fpga_launch_ms)
            if kernel == MMADD:
                return self._mm_time(n, self.fpga_mm_gflops, self.fpga_launch_ms) + self._ma_time(
                    n, self.fpga_ma_bw_gbs, 0.0
                )
        raise ValueError(f"unmirrored kernel {kernel!r} on {kind}")

    def transfer_time_ms(self, nbytes):
        return self.bus_latency_ms + float(nbytes) / (self.bus_bandwidth_gbs * 1e9) * 1e3


# ------------------------------------------------------------------- dag

class Dag:
    """Mirror of dag::graph::Dag (arena of nodes + edges)."""

    def __init__(self):
        self.nodes = []  # (name, kernel, size)
        self.edges = []  # (src, dst, bytes)
        self.succs = []  # list[list[eid]]
        self.preds = []

    def add_node(self, name, kernel, size):
        self.nodes.append((name, kernel, size))
        self.succs.append([])
        self.preds.append([])
        return len(self.nodes) - 1

    def add_edge(self, src, dst, nbytes=None):
        if nbytes is None:
            size = self.nodes[src][2]
            nbytes = 4 * size * size
        eid = len(self.edges)
        self.edges.append((src, dst, nbytes))
        self.succs[src].append(eid)
        self.preds[dst].append(eid)
        return eid

    def node_count(self):
        return len(self.nodes)

    def in_degree(self, v):
        return len(self.preds[v])

    def out_degree(self, v):
        return len(self.succs[v])

    def sinks(self):
        return [v for v in range(len(self.nodes)) if not self.succs[v]]


def paper_gen_cfg(kernel, size):
    return dict(kernels=38, edges=75, layers=7, kernel=kernel, size=size, seed=2015, source=False)


def scaled_gen_cfg(kernels, kernel, size, seed):
    return dict(
        kernels=kernels,
        edges=kernels * 2 - 1,
        layers=int(math.ceil(math.sqrt(kernels))),
        kernel=kernel,
        size=size,
        seed=seed,
        source=False,
    )


def generate_layered(cfg):
    """Mirror of dag::generator::generate_layered (PCG32 call order)."""
    rng = pm.Pcg32.seeded(cfg["seed"])
    n = cfg["kernels"]
    layers = max(1, min(cfg["layers"], n))

    layer_of = [0] * n
    for l in range(min(layers, n)):
        layer_of[l] = l
    for i in range(layers, n):
        layer_of[i] = rng.gen_range(layers)
    rng.shuffle(layer_of)

    per_layer = [0] * layers
    for l in layer_of:
        per_layer[l] += 1
    prefix = total = 0
    for l in range(layers):
        total += per_layer[l] * prefix
        prefix += per_layer[l]
    assert cfg["edges"] <= total, "edge target infeasible"

    dag = Dag()
    ids = [dag.add_node(f"k{i}", cfg["kernel"], cfg["size"]) for i in range(n)]

    by_layer = [[] for _ in range(layers)]
    for i, l in enumerate(layer_of):
        by_layer[l].append(ids[i])
    earlier = []
    acc = []
    for l in range(layers):
        earlier.append(list(acc))
        acc.extend(by_layer[l])

    have = set()
    edges_left = cfg["edges"]

    for l in range(1, layers):
        for v in by_layer[l]:
            pool = earlier[l]
            parents = min(2, len(pool), edges_left)
            tries = 0
            added = 0
            while added < parents and tries < 32:
                tries += 1
                u = rng.choose(pool)
                if (u, v) not in have:
                    have.add((u, v))
                    dag.add_edge(u, v)
                    edges_left -= 1
                    added += 1
            if edges_left == 0:
                break

    guard = 0
    while edges_left > 0:
        guard += 1
        assert guard < 1_000_000
        l = 1 + rng.gen_range(layers - 1)
        if not by_layer[l] or not earlier[l]:
            continue
        v = rng.choose(by_layer[l])
        u = rng.choose(earlier[l])
        if (u, v) not in have:
            have.add((u, v))
            dag.add_edge(u, v)
            edges_left -= 1

    if cfg["source"]:
        src = dag.add_node("__source", SOURCE, cfg["size"])
        for i in ids:
            if dag.in_degree(i) == 0:
                dag.add_edge(src, i)
    return dag


def phased(width, depth, size):
    """Mirror of workloads::phased."""
    g = Dag()
    prev = []
    for phase, kernel in [(0, MM), (1, MA)]:
        for layer in range(depth):
            tag = "mm" if phase == 0 else "ma"
            cur = [g.add_node(f"{tag}_{layer}_{i}", kernel, size) for i in range(width)]
            if prev:
                for i, v in enumerate(cur):
                    g.add_edge(prev[i], v)
                    g.add_edge(prev[(i + 1) % width], v)
            prev = cur
    return g


def chain(length, kernel, size):
    g = Dag()
    ids = [g.add_node(f"c{i}", kernel, size) for i in range(length)]
    for a, b in zip(ids, ids[1:]):
        g.add_edge(a, b)
    return g


# ------------------------------------------------------------ gp weights

def _round_half_away(x):
    return math.floor(x + 0.5)  # positive domain only


def node_weight_us(model, kernel, n, k_devices, policy="gpu"):
    if kernel == SOURCE:
        return 0
    cpu = model.kernel_time_ms(kernel, n, 0)
    last = k_devices - 1
    gpu = model.kernel_time_ms(kernel, n, 1 if last >= 1 else last)
    ms = {"gpu": gpu, "cpu": cpu, "mean": 0.5 * (cpu + gpu)}[policy]
    return int(max(_round_half_away(ms * 1000.0), 1))


def edge_weight_us(model, nbytes):
    return int(_round_half_away(model.transfer_time_ms(nbytes) * 1000.0))


def aggregate_ratios(dag, k, model, only=None):
    totals = [0.0] * k
    for v, (_, kernel, size) in enumerate(dag.nodes):
        if kernel == SOURCE or (only is not None and not only[v]):
            continue
        for d in range(k):
            totals[d] += model.kernel_time_ms(kernel, size, d)
    inv = [1.0 / max(t, 1e-12) for t in totals]
    s = sum(inv)
    return [i / s for i in inv]


def build_gp_graph(dag, model, k, policy="gpu"):
    """Mirror of GraphPartition::build_graph: node/edge µs weights plus
    the pinned host anchor as vertex n."""
    n = dag.node_count()
    vwgt = [
        max(node_weight_us(model, kernel, size, k, policy), 0)
        for (_, kernel, size) in dag.nodes
    ]
    edges = [(s, d, max(edge_weight_us(model, b), 1)) for (s, d, b) in dag.edges]
    anchor = n
    vwgt.append(0)
    anchor_w = [0] * n
    for v, (_, kernel, size) in enumerate(dag.nodes):
        if kernel == SOURCE:
            continue
        mat_bytes = 4 * size * size
        w = (ARITY[kernel] - min(dag.in_degree(v), ARITY[kernel])) * edge_weight_us(
            model, mat_bytes
        )
        if dag.out_degree(v) == 0:
            w += edge_weight_us(model, mat_bytes)
        if w > 0:
            edges.append((anchor, v, w))
            anchor_w[v] = w
    return vwgt, edges, anchor_w


def gp_plan(dag, k, model, epsilon=0.05, seed=1, node_weight="gpu"):
    n = dag.node_count()
    vwgt, edges, _ = build_gp_graph(dag, model, k, node_weight)
    g = pm.csr_build(vwgt, edges)
    fixed = [-1] * n + [0]
    ratios = aggregate_ratios(dag, k, model)
    cfg = pm.default_cfg(k=k, targets=list(ratios), epsilon=epsilon, seed=seed, fixed=fixed)
    res = pm.partition(g, cfg)
    return res["parts"][:n], ratios, res


# --------------------------------------------------------------- policies

class Eager:
    name = "eager"

    def select(self, ctx):
        free = ctx["device_free"]
        best = 0
        for d in range(1, len(free)):
            if free[d] <= free[best]:
                best = d
        return best

    def on_task_finish(self, task, dev, finish_ms):
        pass


def _transfer_cost(ctx, dev):
    cost = 0.0
    for (nbytes, mask) in ctx["inputs"]:
        if not (mask >> dev) & 1:  # memory_node(dev) == dev (identity)
            cost += ctx["model"].transfer_time_ms(nbytes)
    return cost


def _estimated_finish(ctx, dev):
    data_ready = ctx["ready"] + _transfer_cost(ctx, dev)
    start = max(ctx["device_free"][dev], data_ready)
    return start + ctx["model"].kernel_time_ms(ctx["kernel"], ctx["size"], dev)


def _least_slack_meeting(ctx):
    """Mirror of sched::dmda::least_slack_meeting: among devices whose
    EFT meets the deadline, the one finishing *latest* (least slack)."""
    deadline = ctx["deadline"]
    best = None
    best_t = -math.inf
    for d in range(len(ctx["device_free"])):
        t = _estimated_finish(ctx, d)
        if t <= deadline and t > best_t:
            best_t = t
            best = d
    return best


class Dmda:
    name = "dmda"

    def select(self, ctx):
        if math.isfinite(ctx["deadline"]):
            d = _least_slack_meeting(ctx)
            if d is not None:
                return d
        best = 0
        best_t = math.inf
        for d in range(len(ctx["device_free"])):
            t = _estimated_finish(ctx, d)
            if t < best_t:
                best_t = t
                best = d
        return best

    def on_task_finish(self, task, dev, finish_ms):
        pass


class PinAll:
    def __init__(self, device):
        self.device = device
        self.name = {0: "cpu-only", 1: "gpu-only"}.get(device, "pin")

    def select(self, ctx):
        return self.device

    def on_task_finish(self, task, dev, finish_ms):
        pass


class Gp:
    """One-shot graph partition (plan once, table lookup)."""

    def __init__(self, dag, k, model, epsilon=0.05, seed=1, node_weight="gpu"):
        self.name = "gp"
        self.parts, self.ratios, self.result = gp_plan(
            dag, k, model, epsilon, seed, node_weight
        )

    def select(self, ctx):
        return self.parts[ctx["task"]]

    def on_task_finish(self, task, dev, finish_ms):
        pass


class GpWindow:
    """Mirror of GraphPartition with window=W (frontier replanning).
    incremental=True (the Rust default) warm-starts each replan from the
    previous pin table (pm.partition_warm: greedy warm_place for free
    vertices + a single boundary refinement pass on the fine graph, no
    coarsening), folds the select-time device-free horizon into the
    replan targets, and skips replans whose frontier epoch is
    unchanged; incremental=False is the from-scratch baseline arm (full
    multilevel on every replan, never skips)."""

    def __init__(self, dag, k, model, window, epsilon=0.05, seed=1,
                 node_weight="gpu", incremental=True):
        self.name = "gp-window"
        self.window = window
        self.epsilon = epsilon
        self.seed = seed
        self.k = k
        self.incremental = incremental
        self.parts, self.ratios, self.result = gp_plan(
            dag, k, model, epsilon, seed, node_weight
        )
        n = dag.node_count()
        self.node_w, all_edges, self.anchor_w = build_gp_graph(dag, model, k, node_weight)
        self.node_w = self.node_w[:n]
        self.dag_edges = [(s, d, max(edge_weight_us(model, b), 1)) for (s, d, b) in dag.edges]
        self.dev_time = [
            [model.kernel_time_ms(kernel, size, d) for d in range(k)]
            for (_, kernel, size) in dag.nodes
        ]
        self.real = [kernel != SOURCE for (_, kernel, _) in dag.nodes]
        self.dispatched = [False] * n
        self.finishes = 0
        self.replans = 0
        # Mirror of GraphPartition's epoch diff + ReplanStats: on_submit
        # has already bumped the epoch once by the time the job runs.
        self.frontier_epoch = 1
        self.last_replan_epoch = None
        self.rstats = dict(replans=0, skipped=0, cost_ns=0)
        # Mirror of GraphPartition dev_free_ms / per-job merged flag
        # (see OpenGpWindow): the solo plan ignores nothing in the
        # closed single-job case, but the first executed replan still
        # re-seeds via warm_place for parity with the open path.
        self.dev_free = [0.0] * k
        self.merged = False

    def select(self, ctx):
        v = ctx["task"]
        if math.isfinite(ctx["deadline"]) and _estimated_finish(ctx, self.parts[v]) > ctx["deadline"]:
            d = _least_slack_meeting(ctx)
            if d is not None:
                self.parts[v] = d
        if not self.dispatched[v]:
            # First dispatch: the task leaves the replannable frontier.
            self.frontier_epoch += 1
        self.dev_free = list(ctx["device_free"])
        self.dispatched[v] = True
        return self.parts[v]

    def on_task_finish(self, task, dev, finish_ms):
        self.finishes += 1
        if self.finishes < self.window:
            return
        self.finishes = 0
        self._replan()

    def _replan(self):
        if self.incremental and self.last_replan_epoch == self.frontier_epoch:
            self.rstats["skipped"] += 1
            return
        t0 = time.perf_counter_ns()
        n = len(self.node_w)
        totals = [0.0] * self.k
        remaining = 0
        for v in range(n):
            if not self.real[v] or self.dispatched[v]:
                continue
            remaining += 1
            for d in range(self.k):
                totals[d] += self.dev_time[v][d]
        if remaining == 0:
            return
        # Backlog-aware targets (see OpenGpWindow._replan for the
        # derivation): equalize projected completion over the relative
        # per-device free horizons snapshotted at the last select.
        finite = [f for f in self.dev_free if math.isfinite(f)]
        mn = min(finite) if finite else 0.0
        blog = [min(f - mn, 1e7) if math.isfinite(f) else 1e7
                for f in self.dev_free]
        inv = [1.0 / max(t, 1e-12) for t in totals]
        c = (1.0 + sum(b * i for b, i in zip(blog, inv))) / sum(inv)
        ratios = [max((c - b) * i, 1e-3) for b, i in zip(blog, inv)]
        rsum = sum(ratios)
        ratios = [r / rsum for r in ratios]

        vwgt = list(self.node_w) + [0]
        anchor = n
        edges = [(anchor, v, self.anchor_w[v]) for v in range(n) if self.anchor_w[v] > 0]
        edges.extend(self.dag_edges)
        fixed = [-1] * (n + 1)
        fixed[anchor] = 0
        for v in range(n):
            if self.dispatched[v]:
                fixed[v] = self.parts[v]
        g = pm.csr_build(vwgt, edges)
        cfg = pm.default_cfg(
            k=self.k, targets=ratios, epsilon=self.epsilon, seed=self.seed, fixed=fixed
        )
        if self.incremental:
            # Never-merged vertices enter free (-1) so warm_place seeds
            # them target-aware (parity with the open multi-job path).
            warm = ([p if self.merged else -1 for p in self.parts]) + [0]
            res = pm.partition_warm(g, cfg, warm)
        else:
            res = pm.partition(g, cfg)
        self.merged = True
        self.parts = res["parts"][:n]
        self.ratios = ratios
        self.result = res
        self.replans += 1
        self.last_replan_epoch = self.frontier_epoch
        self.rstats["replans"] += 1
        self.rstats["cost_ns"] += time.perf_counter_ns() - t0


# ----------------------------------------------------------------- engine

def simulate(dag, policy, workers, model, bus_channels=1, prefetch=False, return_to_host=True):
    """Mirror of sim::engine::simulate (list-scheduling discrete-event)."""
    import heapq

    n = dag.node_count()
    k = len(workers)
    host = 0

    # Data directory: out handles 0..n-1, then initial buffers.
    bytes_of = []
    mask_of = []

    def alloc(nbytes, mask):
        bytes_of.append(nbytes)
        mask_of.append(mask)
        return len(bytes_of) - 1

    out = []
    for v, (_, kernel, size) in enumerate(dag.nodes):
        out.append(alloc(4 * size * size, 0))
    initial = []
    for v, (_, kernel, size) in enumerate(dag.nodes):
        missing = max(ARITY[kernel] - dag.in_degree(v), 0)
        initial.append([alloc(4 * size * size, 1 << host) for _ in range(missing)])

    worker_free = [[0.0] * w for w in workers]
    bus = [0.0] * max(bus_channels, 1)
    avail = [0.0] * len(bytes_of)
    ledger_count = 0
    ledger_bytes = 0
    indeg = [dag.in_degree(v) for v in range(n)]
    ready_time = [0.0] * n
    finish = [0.0] * n
    assignments = [None] * n
    device_busy = [0.0] * k
    tasks_per_device = [0] * k

    heap = [(0.0, v) for v in range(n) if indeg[v] == 0]
    heapq.heapify(heap)

    executed = 0
    executed_ms = 0.0
    while heap:
        ready, v = heapq.heappop(heap)
        executed += 1
        name, kernel, size = dag.nodes[v]

        if kernel == SOURCE:
            mask_of[out[v]] = 1 << host
            finish[v] = ready
            assignments[v] = host
            for e in dag.succs[v]:
                w = dag.edges[e][1]
                indeg[w] -= 1
                ready_time[w] = max(ready_time[w], ready)
                if indeg[w] == 0:
                    heapq.heappush(heap, (ready_time[w], w))
            continue

        handles = [out[dag.edges[e][0]] for e in dag.preds[v]] + initial[v]
        inputs = [(bytes_of[h], mask_of[h]) for h in handles]
        device_free = [min(ws) for ws in worker_free]

        ctx = dict(
            task=v,
            kernel=kernel,
            size=size,
            ready=ready,
            device_free=device_free,
            inputs=inputs,
            model=model,
            deadline=math.inf,  # closed jobs are untagged
        )
        dev = policy.select(ctx)
        mem = dev  # Platform::memory_node is the identity today

        data_ready = ready
        for h in handles:
            if not (mask_of[h] >> mem) & 1:
                # acquire_read: src = lowest set bit, new copy Shared.
                src = (mask_of[h] & -mask_of[h]).bit_length() - 1
                mask_of[h] |= 1 << mem
                t = model.transfer_time_ms(bytes_of[h])
                ch = min(range(len(bus)), key=lambda c: bus[c])
                earliest = avail[h] if prefetch else ready
                start = max(bus[ch], earliest)
                bus[ch] = start + t
                ledger_count += 1
                ledger_bytes += bytes_of[h]
                data_ready = max(data_ready, bus[ch])
                del src
        mask_of[out[v]] = 1 << mem

        worker = min(range(len(worker_free[dev])), key=lambda i: worker_free[dev][i])
        exec_ms = model.kernel_time_ms(kernel, size, dev)
        executed_ms += exec_ms
        start = max(worker_free[dev][worker], data_ready)
        end = start + exec_ms
        worker_free[dev][worker] = end
        finish[v] = end
        avail[out[v]] = end
        assignments[v] = dev
        device_busy[dev] += exec_ms
        tasks_per_device[dev] += 1
        policy.on_task_finish(v, dev, end)

        for e in dag.succs[v]:
            w = dag.edges[e][1]
            indeg[w] -= 1
            ready_time[w] = max(ready_time[w], end)
            if indeg[w] == 0:
                heapq.heappush(heap, (ready_time[w], w))

    assert executed == n, "cyclic graph or unreachable tasks"

    makespan = 0.0
    for f in finish:
        makespan = max(makespan, f)

    if return_to_host:
        for v in dag.sinks():
            if dag.nodes[v][1] == SOURCE:
                continue
            h = out[v]
            if not (mask_of[h] >> host) & 1:
                mask_of[h] |= 1 << host
                t = model.transfer_time_ms(bytes_of[h])
                ch = min(range(len(bus)), key=lambda c: bus[c])
                start = max(bus[ch], finish[v])
                bus[ch] = start + t
                ledger_count += 1
                ledger_bytes += bytes_of[h]
                makespan = max(makespan, bus[ch])

    return dict(
        makespan=makespan,
        assignments=assignments,
        ledger_count=ledger_count,
        ledger_bytes=ledger_bytes,
        tasks_per_device=tasks_per_device,
        device_busy=device_busy,
        executed_ms=executed_ms,
    )


PAPER_WORKERS = [3, 1]
TRI_WORKERS = [3, 1, 1]


def make_policy(name, dag, model, k, **kw):
    if name == "eager":
        return Eager()
    if name == "dmda":
        return Dmda()
    if name == "gp":
        return Gp(dag, k, model, **kw)
    if name == "gp-window":
        return GpWindow(dag, k, model, **kw)
    if name == "cpu-only":
        return PinAll(0)
    if name == "gpu-only":
        return PinAll(1)
    raise ValueError(name)


def run(dag, name, model=None, workers=None, **kw):
    model = model or CalibratedModel()
    workers = workers or PAPER_WORKERS
    sim_kw = {key: kw.pop(key) for key in list(kw) if key in ("bus_channels", "prefetch", "return_to_host")}
    policy = make_policy(name, dag, model, len(workers), **kw)
    r = simulate(dag, policy, workers, model, **sim_kw)
    r["policy"] = policy
    return r


# -------------------------------------------------- open-system engine
#
# Transliteration of sim::engine::EngineCore (PR 4 + PR 5 QoS + PR 6
# faults): one global event heap ordered by (time, kind, job, task,
# epoch) with kind 0=dev-down, 1=dev-up, 2=drain, 3=arrival, 4=ready,
# 5=reject; many jobs share worker_free / bus / directory; a bounded
# admission window (queue) holds excess arrivals in a pending queue
# ordered by the admission policy (fifo / edf / sjf / reject with wait
# budgets); a FaultSpec-mirror dict injects device failures/drains and
# the engine rolls in-flight work back (epoch-tagged ready events kill
# stale dispatches).

EV_DOWN, EV_UP, EV_DRAIN, EV_ARRIVAL, EV_READY, EV_REJECT = 0, 1, 2, 3, 4, 5


# --------------------------------------------------- event-queue mirror
#
# Mirror of sim::equeue (keep in sync): the EventQueue seam with the
# BinaryHeap reference and the ladder queue. Events are plain tuples
# (time, kind, job, task, epoch); Python tuple comparison is the same
# lexicographic total order the Rust engine uses, so both
# implementations must produce identical pop sequences.

LADDER_BUCKETS = 64
LADDER_SPILL = 64
LADDER_MAX_RUNGS = 8


class HeapQueue:
    """Mirror of equeue::HeapQueue (heapq on the full event tuple)."""

    def __init__(self):
        self._h = []

    def schedule(self, ev):
        heapq.heappush(self._h, ev)

    def pop(self):
        return heapq.heappop(self._h) if self._h else None

    def __len__(self):
        return len(self._h)


class _Rung:
    """Mirror of equeue::Rung."""

    __slots__ = ("start", "width", "cur", "buckets")

    def __init__(self, start, width):
        self.start = start
        self.width = width
        self.cur = 0
        self.buckets = [[] for _ in range(LADDER_BUCKETS)]

    def bstart(self, i):
        return self.start + i * self.width

    def bucket_index(self, t):
        n = len(self.buckets)
        # Rust `as usize` saturates (negative -> 0, huge -> MAX).
        idx = int((t - self.start) / self.width) if self.width > 0.0 else 0
        idx = min(max(idx, 0), n - 1)
        while idx + 1 < n and self.bstart(idx + 1) <= t:
            idx += 1
        while idx > 0 and self.bstart(idx) > t:
            idx -= 1
        return idx


class LadderQueue:
    """Mirror of equeue::LadderQueue: unsorted far-future top band, a
    rung stack of fixed bucket arrays, and a descending-sorted bottom
    band popped from the end."""

    def __init__(self):
        self.top = []
        self.top_start = -math.inf
        self.rungs = []
        self.bottom = []
        self.last_time = -math.inf
        self.size = 0

    def _spawn_or_spill(self, events):
        parent = self.rungs[-1]
        start = parent.bstart(parent.cur)
        width = parent.width / LADDER_BUCKETS
        tmin = min(e[0] for e in events)
        tmax = max(e[0] for e in events)
        if (
            len(events) <= LADDER_SPILL
            or len(self.rungs) >= LADDER_MAX_RUNGS
            or tmin == tmax
            or width <= 0.0
        ):
            events.sort(reverse=True)
            self.bottom = events
            parent.cur += 1
            return
        child = _Rung(start, width)
        for ev in events:
            child.buckets[child.bucket_index(ev[0])].append(ev)
        # The parent's cur is NOT advanced: the child rung *is* that
        # bucket; the parent advances when the child rung empties.
        self.rungs.append(child)

    def schedule(self, ev):
        t = ev[0]
        assert t >= self.last_time, f"event scheduled in the past: {t} < {self.last_time}"
        self.size += 1
        if t > self.top_start:
            self.top.append(ev)
            return
        innermost = len(self.rungs) - 1
        for ri, rung in enumerate(self.rungs):
            idx = rung.bucket_index(t)
            if idx < rung.cur:
                continue
            if idx == rung.cur and ri != innermost:
                continue  # delegated to the child rung
            rung.buckets[idx].append(ev)
            return
        # Below every active rung region: merge into the sorted bottom.
        lo, hi = 0, len(self.bottom)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.bottom[mid] > ev:
                lo = mid + 1
            else:
                hi = mid
        self.bottom.insert(lo, ev)

    def pop(self):
        if self.size == 0:
            return None
        while not self.bottom:
            if self.rungs:
                rung = self.rungs[-1]
                while rung.cur < LADDER_BUCKETS and not rung.buckets[rung.cur]:
                    rung.cur += 1
                if rung.cur == LADDER_BUCKETS:
                    self.rungs.pop()
                    if self.rungs:
                        self.rungs[-1].cur += 1
                    continue
                events = rung.buckets[rung.cur]
                rung.buckets[rung.cur] = []
                self._spawn_or_spill(events)
                continue
            tmin = min(e[0] for e in self.top)
            tmax = max(e[0] for e in self.top)
            events = self.top
            self.top = []
            # Strict `>` routing into top keeps same-time arrivals at
            # top_start flowing into the active structure below it.
            self.top_start = tmax
            if len(events) <= LADDER_SPILL or tmin == tmax:
                events.sort(reverse=True)
                self.bottom = events
            else:
                rung = _Rung(tmin, (tmax - tmin) / LADDER_BUCKETS)
                for ev in events:
                    rung.buckets[rung.bucket_index(ev[0])].append(ev)
                self.rungs.append(rung)
        ev = self.bottom.pop()
        self.last_time = ev[0]
        self.size -= 1
        return ev

    def __len__(self):
        return self.size


def make_equeue(kind):
    """Mirror of EventQueueKind::build ("heap" | "ladder")."""
    if kind == "heap":
        return HeapQueue()
    if kind == "ladder":
        return LadderQueue()
    raise ValueError(kind)


def exp_mean_ms(rng, mean):
    """Mirror of sim::engine::exp_mean_ms."""
    return -math.log(1.0 - rng.gen_f64()) * mean


def dag_signature(dag):
    """Structural plan-cache key (mirror of PlanKey's dag fingerprint
    role: names excluded, structure + sizes included)."""
    return (
        tuple((kernel, size) for (_, kernel, size) in dag.nodes),
        tuple(dag.edges),
    )


class OpenEager(Eager):
    def on_submit(self, job, dag):
        pass

    def on_task_finish(self, job, task, dev, finish_ms):
        pass

    def on_job_drain(self, job):
        pass

    def on_task_killed(self, job, task):
        pass

    def on_device_down(self, dev):
        return 0

    def on_device_up(self, dev):
        return 0


class OpenDmda(Dmda):
    def on_submit(self, job, dag):
        pass

    def on_task_finish(self, job, task, dev, finish_ms):
        pass

    def on_job_drain(self, job):
        pass

    def on_task_killed(self, job, task):
        pass

    def on_device_down(self, dev):
        return 0

    def on_device_up(self, dev):
        return 0


class OpenPin(PinAll):
    def on_submit(self, job, dag):
        pass

    def on_task_finish(self, job, task, dev, finish_ms):
        pass

    def on_job_drain(self, job):
        pass

    def on_task_killed(self, job, task):
        pass

    def on_device_down(self, dev):
        return 0

    def on_device_up(self, dev):
        return 0


class OpenGp:
    """Mirror of GraphPartition (one-shot) under the job-tagged
    lifecycle: per-job pin tables, plans cached by structure."""

    name = "gp"

    def __init__(self, k, model, epsilon=0.05, seed=1, node_weight="gpu"):
        self.k = k
        self.model = model
        self.epsilon = epsilon
        self.seed = seed
        self.node_weight = node_weight
        self.plan_cache = {}
        self.hits = 0
        self.misses = 0
        self.parts = {}

    def _pins(self, dag):
        key = dag_signature(dag)
        if key in self.plan_cache:
            self.hits += 1
            return self.plan_cache[key]
        self.misses += 1
        pins, _, _ = gp_plan(
            dag, self.k, self.model, self.epsilon, self.seed, self.node_weight
        )
        self.plan_cache[key] = pins
        return pins

    def on_submit(self, job, dag):
        self.parts[job] = list(self._pins(dag))

    def select(self, ctx):
        return self.parts[ctx["job"]][ctx["task"]]

    def on_task_finish(self, job, task, dev, finish_ms):
        pass

    def on_job_drain(self, job):
        pass

    def on_task_killed(self, job, task):
        # One-shot plans re-dispatch from the same table (window=None in
        # the Rust scheduler: no frontier state to roll back).
        pass

    def on_device_down(self, dev):
        return 0

    def on_device_up(self, dev):
        return 0


class OpenGpWindow:
    """Mirror of GraphPartition with window=W under the open system:
    every W completions, re-partition the undispatched *union frontier*
    of all in-flight jobs (their vertices concatenated in job-id order
    plus one shared host anchor), dispatched tasks pinned. With
    incremental=True (the Rust default) each replan warm-starts from the
    previous per-job pin tables (pm.partition_warm: greedy warm_place
    for never-merged jobs' free vertices + one boundary refinement pass
    on the fine merged graph, no coarsening), folds the select-time
    device-free horizon into the replan targets, and a replan whose
    frontier epoch is unchanged since the last one is skipped outright;
    incremental=False is the from-scratch baseline arm."""

    name = "gp-window"

    def __init__(self, k, model, window, epsilon=0.05, seed=1, node_weight="gpu",
                 incremental=True):
        self.k = k
        self.model = model
        self.window = window
        self.epsilon = epsilon
        self.seed = seed
        self.node_weight = node_weight
        self.incremental = incremental
        self.plan_cache = {}
        self.hits = 0
        self.misses = 0
        self.jobs = {}
        self.finishes = 0
        self.replans = 0
        # Mirror of GraphPartition.frontier_epoch / last_replan_epoch /
        # ReplanStats (None = the u64::MAX "never replanned" sentinel).
        self.frontier_epoch = 0
        self.last_replan_epoch = None
        self.rstats = dict(replans=0, skipped=0, cost_ns=0)
        # Optional instrumentation: when set to a list, every executed
        # incremental replan also runs the from-scratch partitioner on
        # the same merged graph and appends (warm_cut, scratch_cut) —
        # how run_checks measures the 2% cut-parity margin.
        self.record_cuts = None
        # Backlog-aware replan targets (mirror of GraphPartition
        # dev_free_ms): select() snapshots the engine's per-device
        # free-horizon estimate; _replan folds the relative backlog
        # (free[d] - min free) into the k-way targets so the merged
        # partition equalizes projected completion times instead of raw
        # remaining-work shares. The equalization is invariant to a
        # common offset, so no "now" clock is needed.
        self.dev_free = [0.0] * k

    def _pins(self, dag):
        key = dag_signature(dag)
        if key in self.plan_cache:
            self.hits += 1
            return self.plan_cache[key]
        self.misses += 1
        pins, _, _ = gp_plan(
            dag, self.k, self.model, self.epsilon, self.seed, self.node_weight
        )
        self.plan_cache[key] = pins
        return pins

    def on_submit(self, job, dag):
        pins = self._pins(dag)
        self.frontier_epoch += 1  # admission changes the union frontier
        # Reset the window counter only when the system was idle (an
        # admission must not starve the in-flight jobs' replan cadence).
        if not any(st["active"] for st in self.jobs.values()):
            self.replans = 0
            self.finishes = 0
        n = dag.node_count()
        node_w, _, anchor_w = build_gp_graph(dag, self.model, self.k, self.node_weight)
        self.jobs[job] = dict(
            active=True,
            merged=False,
            parts=list(pins),
            dispatched=[False] * n,
            node_w=node_w[:n],
            anchor_w=anchor_w,
            edges=[
                (s, d, max(edge_weight_us(self.model, b), 1)) for (s, d, b) in dag.edges
            ],
            dev_time=[
                [self.model.kernel_time_ms(kernel, size, d) for d in range(self.k)]
                for (_, kernel, size) in dag.nodes
            ],
            real=[kernel != SOURCE for (_, kernel, _) in dag.nodes],
        )

    def select(self, ctx):
        st = self.jobs[ctx["job"]]
        v = ctx["task"]
        if math.isfinite(ctx["deadline"]) and _estimated_finish(ctx, st["parts"][v]) > ctx["deadline"]:
            d = _least_slack_meeting(ctx)
            if d is not None:
                st["parts"][v] = d
        if not st["dispatched"][v]:
            # First dispatch: the task leaves the replannable frontier
            # and becomes a pin.
            self.frontier_epoch += 1
        self.dev_free = list(ctx["device_free"])
        st["dispatched"][v] = True
        return st["parts"][v]

    def on_task_finish(self, job, task, dev, finish_ms):
        self.finishes += 1
        if self.finishes >= self.window:
            self.finishes = 0
            self._replan()

    def on_job_drain(self, job):
        if self.jobs[job]["active"]:
            self.frontier_epoch += 1
        self.jobs[job]["active"] = False

    def on_task_killed(self, job, task):
        # Mirror of GraphPartition::on_task_killed: the job is live
        # again and the victim re-enters the replan frontier.
        st = self.jobs[job]
        st["active"] = True
        if task < len(st["dispatched"]):
            st["dispatched"][task] = False
        self.frontier_epoch += 1

    def on_device_down(self, dev):
        # The epoch bump *before* replanning guarantees the incremental
        # fast exit never swallows a forced recovery replan.
        before = self.replans
        self.finishes = 0
        self.frontier_epoch += 1
        self._replan()
        return self.replans - before

    def on_device_up(self, dev):
        before = self.replans
        self.finishes = 0
        self.frontier_epoch += 1
        self._replan()
        return self.replans - before

    def _replan(self):
        # No-change fast exit (incremental mode): an unchanged frontier
        # epoch means this replan would reproduce the previous
        # (deterministic) result verbatim.
        if self.incremental and self.last_replan_epoch == self.frontier_epoch:
            self.rstats["skipped"] += 1
            return
        t0 = time.perf_counter_ns()
        active = [j for j in sorted(self.jobs) if self.jobs[j]["active"]]
        if not active:
            return
        totals = [0.0] * self.k
        remaining = 0
        for j in active:
            st = self.jobs[j]
            for v in range(len(st["node_w"])):
                if not st["real"][v] or st["dispatched"][v]:
                    continue
                remaining += 1
                for d in range(self.k):
                    totals[d] += st["dev_time"][v][d]
        if remaining == 0:
            return
        # Backlog-aware targets: device d finishes its dispatched backlog
        # B_d plus an x_d share of the remaining frontier at B_d + x_d*R_d
        # (R_d = time if the whole frontier ran on d); equalizing the
        # projected finish times gives x_d = (C - B_d) / R_d with
        # C = (1 + sum B/R) / sum 1/R, clamped and renormalized. B_d is
        # the relative free horizon from the last select snapshot (a
        # down device's inf horizon caps into a tiny clamped share).
        finite = [f for f in self.dev_free if math.isfinite(f)]
        mn = min(finite) if finite else 0.0
        blog = [min(f - mn, 1e7) if math.isfinite(f) else 1e7
                for f in self.dev_free]
        inv = [1.0 / max(t, 1e-12) for t in totals]
        c = (1.0 + sum(b * i for b, i in zip(blog, inv))) / sum(inv)
        ratios = [max((c - b) * i, 1e-3) for b, i in zip(blog, inv)]
        rsum = sum(ratios)
        ratios = [r / rsum for r in ratios]

        offsets = {}
        vwgt = []
        for j in active:
            offsets[j] = len(vwgt)
            vwgt.extend(self.jobs[j]["node_w"])
        total_n = len(vwgt)
        anchor = total_n
        vwgt.append(0)
        edges = []
        for j in active:
            st = self.jobs[j]
            off = offsets[j]
            for v in range(len(st["node_w"])):
                if st["anchor_w"][v] > 0:
                    edges.append((anchor, off + v, st["anchor_w"][v]))
        for j in active:
            st = self.jobs[j]
            off = offsets[j]
            for (u, v, w) in st["edges"]:
                edges.append((off + u, off + v, w))
        fixed = [-1] * (total_n + 1)
        fixed[anchor] = 0
        for j in active:
            st = self.jobs[j]
            off = offsets[j]
            for v in range(len(st["dispatched"])):
                if st["dispatched"][v]:
                    fixed[off + v] = st["parts"][v]
        g = pm.csr_build(vwgt, edges)
        cfg = pm.default_cfg(
            k=self.k, targets=ratios, epsilon=self.epsilon, seed=self.seed, fixed=fixed
        )
        if self.incremental:
            # Warm start: scatter the previous per-job pin tables over
            # the merged graph; the anchor warm-starts on its host pin.
            # Jobs that never went through a merged replan only carry
            # their solo-plan pins, which ignore the rest of the system
            # — mark their vertices free (-1) so warm_place seeds them
            # target-aware instead.
            warm = [0] * (total_n + 1)
            for j in active:
                off = offsets[j]
                st = self.jobs[j]
                for v, p in enumerate(st["parts"]):
                    warm[off + v] = p if st["merged"] else -1
            res = pm.partition_warm(g, cfg, warm)
        else:
            res = pm.partition(g, cfg)
        for j in active:
            self.jobs[j]["merged"] = True
        for j in active:
            off = offsets[j]
            n = len(self.jobs[j]["node_w"])
            self.jobs[j]["parts"] = res["parts"][off:off + n]
        self.replans += 1
        self.last_replan_epoch = self.frontier_epoch
        self.rstats["replans"] += 1
        self.rstats["cost_ns"] += time.perf_counter_ns() - t0
        if self.incremental and self.record_cuts is not None:
            # Outside the timed window: the scratch run exists only to
            # measure cut parity, not to bill the incremental arm.
            self.record_cuts.append(
                (res["edge_cut"], pm.partition(g, cfg)["edge_cut"])
            )


def est_total_work(dag, model, k):
    """Mirror of sim::engine::est_total_work_ms: sum of best-device
    kernel times."""
    total = 0.0
    for (_, kernel, size) in dag.nodes:
        if kernel == SOURCE:
            continue
        best = math.inf
        for d in range(k):
            t = model.kernel_time_ms(kernel, size, d)
            if t < best:
                best = t
        total += best
    return total


def default_qos():
    return dict(cls=0, prio=0, deadline=math.inf, budget=math.inf)


def f64_total_key(x):
    """Sort key matching Rust ``f64::total_cmp`` (IEEE-754 totalOrder):
    -NaN < -inf < … < -0.0 < +0.0 < … < +inf < +NaN. Plain Python
    ``<`` would raise nothing but order NaN arbitrarily."""
    import struct

    bits = struct.unpack("<q", struct.pack("<d", x))[0]
    return bits ^ 0x7FFFFFFFFFFFFFFF if bits < 0 else bits


class AdmissionCore:
    """Bit-exact twin of ``sim::admission::AdmissionCore``: the bounded
    admission window both Rust engines (simulated and real-executor)
    share. Pops are ordered by the policy's composite key
    ``(priority, deadline, est_work, submit_seq)`` under totalOrder
    float comparison, so the pop sequence here must match the Rust core
    exactly — including NaN keys, which sort last instead of raising."""

    def __init__(self, capacity, policy):
        self.policy = policy
        self.capacity = max(capacity, 1)
        self.inflight = 0
        self.pending = []  # dict(job, prio, deadline_abs, est_work)

    def has_slot(self):
        return self.inflight < self.capacity

    def note_admitted(self):
        self.inflight += 1

    def release_slot(self):
        self.inflight = max(self.inflight - 1, 0)

    def key_of(self, e):
        if self.policy in ("fifo", "reject"):
            return (0, f64_total_key(0.0), f64_total_key(0.0), e["job"])
        if self.policy == "edf":
            return (e["prio"], f64_total_key(e["deadline_abs"]), f64_total_key(0.0), e["job"])
        if self.policy == "sjf":
            return (e["prio"], f64_total_key(e["est_work"]), f64_total_key(0.0), e["job"])
        raise ValueError(self.policy)

    def push_pending(self, job, prio, deadline_abs, est_work):
        self.pending.append(
            dict(job=job, prio=prio, deadline_abs=deadline_abs, est_work=est_work)
        )

    def pop_pending(self):
        if not self.pending:
            return None
        best = min(range(len(self.pending)), key=lambda i: self.key_of(self.pending[i]))
        return self.pending.pop(best)["job"]

    def remove_pending(self, job):
        for i, e in enumerate(self.pending):
            if e["job"] == job:
                self.pending.pop(i)
                return True
        return False

    def pending_len(self):
        return len(self.pending)

    def pending_est_work(self):
        return sum(e["est_work"] for e in self.pending)

    def predicts_reject(self, budget):
        return (
            self.policy == "reject"
            and math.isfinite(budget)
            and self.pending_est_work() > budget
        )


def serial_window_admit(submit, i, window, completes):
    """Mirror of coordinator::serial_window_admit — the real engine's
    pre-admission-core FIFO formula, kept as the bit-identity reference
    for the queue=1 closed form."""
    if i < window:
        return submit
    return max(submit, completes[i - window])


def simulate_open_engine(
    jobs_in,
    policy,
    workers,
    model,
    queue,
    bus_channels=1,
    prefetch=False,
    return_to_host=True,
    collect_trace=False,
    qos=None,
    admit="fifo",
    stream_budget=math.inf,
    fault=None,
    equeue="heap",
):
    """Mirror of EngineCore::run: jobs_in = [(dag, submit_ms)]; qos[i]
    (optional) = dict(cls, prio, deadline, budget) with deadline/budget
    relative to submit; admit = fifo | edf | sjf | reject. Under reject
    each job's effective budget is min(per-job, stream_budget) — the
    mirror of StreamConfig::effective_budget_ms. fault (optional) =
    dict(mtbf, mttr, seed, refetch, scripted=[(at, dev, down, drain)]),
    the mirror of FaultSpec; an inert spec (no scripted outages and
    mtbf=inf) behaves exactly like fault=None. equeue selects the event
    queue ("heap" | "ladder"; both pop in the same total order, the
    run_checks sweep pins that). Returns (results, stats) with stats =
    the RecoveryStats mirror (+ events popped, max in-flight, and the
    note_mem-style memory high-water estimate)."""
    import collections

    k = len(workers)
    host = 0
    worker_free = [[0.0] * w for w in workers]
    bus = [0.0] * max(bus_channels, 1)
    bytes_of = []
    mask_of = []
    avail = []
    events = make_equeue(equeue)
    state = dict(completed=0)
    queue = max(queue, 1)
    # The shared admission core (twin of sim::admission): slot
    # accounting + the policy-ordered pending queue, same object the
    # real executor's driver consumes on the Rust side.
    adm = AdmissionCore(queue, admit)
    dev_state = ["up"] * k  # DeviceState mirror: up | draining | down
    stats = dict(
        failures=0, reexec=0, wasted=0.0, executed=0.0, replans=0,
        events=0, max_inflight=0, mem_high_water=0,
    )

    # Memory high-water mirror of EngineCore::note_mem. The Rust
    # formula's constants are layout facts (size_of::<Option<JobRun>>,
    # the arena row, an Event) the mirror approximates with nominal
    # sizes; the *shape* — live slots x per-slot cost, sampled at
    # admission — matches, which is what the capacity bench's
    # O(in-flight) memory claim measures. One divergence: this engine
    # pre-schedules every arrival event up front, so the len(events)
    # term scales with the remaining session here, where the Rust core
    # materializes arrivals lazily and stays O(in-flight).
    memw = dict(live_jobs=0, live_tasks=0, live_handles=0)

    def note_mem():
        b = (
            memw["live_jobs"] * 320
            + memw["live_tasks"] * 48
            + len(events) * 40
            + memw["live_handles"] * 24
            + adm.pending_len() * 8
            # Source-footprint term (mirror of JobSource::bytes): the
            # Rust open path's lazy StreamSource holds one submit time
            # per job.
            + len(jobs_in) * 8
        )
        stats["mem_high_water"] = max(stats["mem_high_water"], b)

    jobs = []
    for j, (dag, submit) in enumerate(jobs_in):
        q = qos[j] if qos else default_qos()
        jobs.append(
            dict(
                dag=dag,
                submit=submit,
                admit=0.0,
                complete=0.0,
                cls=q["cls"],
                prio=q["prio"],
                deadline_abs=submit + q["deadline"],
                est_work=est_total_work(dag, model, k),
                budget=(min(q["budget"], stream_budget) if admit == "reject" else math.inf),
                rejected=False,
                out=None,
                initial=None,
                indeg=None,
                ready_time=None,
                finish=None,
                assignments=None,
                device_busy=[0.0] * k,
                tasks_per_device=[0] * k,
                ledger_count=0,
                ledger_bytes=0,
                trace=[],
                remaining=-1,
                task_epoch=None,
                drain_epoch=0,
            )
        )
        events.schedule((submit, EV_ARRIVAL, j, 0, 0))

    # Fault clocks (mirror of FaultState::new): device 0 never fails —
    # it owns the host checkpoint, so a dispatch target always exists.
    fault_state = None
    if fault is not None and (fault["scripted"] or math.isfinite(fault["mtbf"])):
        frng = pm.Pcg32.seeded(fault["seed"])
        scripted = [collections.deque() for _ in range(k)]
        if not fault["scripted"]:
            for d in range(1, k):
                events.schedule((exp_mean_ms(frng, fault["mtbf"]), EV_DOWN, d, 0, 0))
        else:
            for (at, dev, down, drain) in sorted(fault["scripted"], key=lambda f: f[0]):
                assert 0 < dev < k, f"scripted fault device {dev} out of range"
                scripted[dev].append((at, down, drain))
                events.schedule((at, EV_DOWN, dev, 1 if drain else 0, 0))
                events.schedule((at + down, EV_UP, dev, 0, 0))
        fault_state = dict(spec=fault, rng=frng, scripted=scripted, commits=[])

    def alloc(nbytes, mask, t):
        # New data exists no earlier than its job's admission instant.
        bytes_of.append(nbytes)
        mask_of.append(mask)
        avail.append(t)
        return len(bytes_of) - 1

    def complete_job(j):
        st = jobs[j]
        dag = st["dag"]
        makespan = 0.0
        for f in st["finish"]:
            makespan = max(makespan, f)
        if return_to_host:
            for v in dag.sinks():
                if dag.nodes[v][1] == SOURCE:
                    continue
                h = st["out"][v]
                if not (mask_of[h] >> host) & 1:
                    mask_of[h] |= 1 << host
                    t = model.transfer_time_ms(bytes_of[h])
                    ch = min(range(len(bus)), key=lambda c: bus[c])
                    start = max(bus[ch], st["finish"][v])
                    bus[ch] = start + t
                    st["ledger_count"] += 1
                    st["ledger_bytes"] += bytes_of[h]
                    makespan = max(makespan, bus[ch])
        st["complete"] = max(makespan, st["admit"])
        policy.on_job_drain(j)
        events.schedule((st["complete"], EV_DRAIN, j, 0, st["drain_epoch"]))

    def admit_job(j, now):
        st = jobs[j]
        st["admit"] = now
        policy.on_submit(j, st["dag"])
        dag = st["dag"]
        n = dag.node_count()
        st["out"] = [alloc(4 * size * size, 0, now) for (_, _, size) in dag.nodes]
        st["initial"] = [
            [
                alloc(4 * size * size, 1 << host, now)
                for _ in range(max(ARITY[kernel] - dag.in_degree(v), 0))
            ]
            for v, (_, kernel, size) in enumerate(dag.nodes)
        ]
        st["indeg"] = [dag.in_degree(v) for v in range(n)]
        st["ready_time"] = [now] * n
        st["finish"] = [0.0] * n
        st["assignments"] = [None] * n
        st["task_epoch"] = [0] * n
        st["remaining"] = n
        for v in range(n):
            if st["indeg"][v] == 0:
                events.schedule((now, EV_READY, j, v, 0))
        adm.note_admitted()
        stats["max_inflight"] = max(stats["max_inflight"], adm.inflight)
        st["_nhandles"] = n + sum(len(hs) for hs in st["initial"])
        memw["live_tasks"] += n
        memw["live_handles"] += st["_nhandles"]
        note_mem()
        if st["remaining"] == 0:
            complete_job(j)

    def dispatch(j, v, ready):
        st = jobs[j]
        dag = st["dag"]
        name, kernel, size = dag.nodes[v]

        if kernel == SOURCE:
            mask_of[st["out"][v]] = 1 << host
            st["finish"][v] = ready
            st["assignments"][v] = host
            for e in dag.succs[v]:
                w = dag.edges[e][1]
                st["indeg"][w] -= 1
                st["ready_time"][w] = max(st["ready_time"][w], ready)
                if st["indeg"][w] == 0:
                    events.schedule(
                        (st["ready_time"][w], EV_READY, j, w, st["task_epoch"][w])
                    )
            st["remaining"] -= 1
            if st["remaining"] == 0:
                complete_job(j)
            return

        handles = [st["out"][dag.edges[e][0]] for e in dag.preds[v]] + st["initial"][v]
        inputs = [(bytes_of[h], mask_of[h]) for h in handles]
        # Non-Up devices look infinitely busy so estimators avoid them.
        device_free = [
            min(ws) if dev_state[d] == "up" else math.inf
            for d, ws in enumerate(worker_free)
        ]

        ctx = dict(
            job=j,
            task=v,
            kernel=kernel,
            size=size,
            ready=ready,
            device_free=device_free,
            inputs=inputs,
            model=model,
            deadline=st["deadline_abs"],
        )
        dev = policy.select(ctx)
        if dev_state[dev] != "up":
            # Reroute pinned/planned work off a dead device: cheapest
            # finish over live devices (kernel time only; mirror of
            # EngineCore::dispatch's reroute).
            best = None
            best_t = math.inf
            for d in range(k):
                if dev_state[d] != "up":
                    continue
                t2 = max(min(worker_free[d]), ready) + model.kernel_time_ms(kernel, size, d)
                if t2 < best_t:
                    best_t = t2
                    best = d
            dev = best
        mem = dev  # Platform::memory_node is the identity today

        data_ready = ready
        for h in handles:
            if not (mask_of[h] >> mem) & 1:
                mask_of[h] |= 1 << mem
                t = model.transfer_time_ms(bytes_of[h])
                ch = min(range(len(bus)), key=lambda c: bus[c])
                earliest = avail[h] if prefetch else ready
                start = max(bus[ch], earliest)
                bus[ch] = start + t
                st["ledger_count"] += 1
                st["ledger_bytes"] += bytes_of[h]
                data_ready = max(data_ready, bus[ch])
        mask_of[st["out"][v]] = 1 << mem

        worker = min(range(len(worker_free[dev])), key=lambda i: worker_free[dev][i])
        exec_ms = model.kernel_time_ms(kernel, size, dev)
        start = max(worker_free[dev][worker], data_ready)
        end = start + exec_ms
        worker_free[dev][worker] = end
        st["finish"][v] = end
        avail[st["out"][v]] = end
        st["assignments"][v] = dev
        st["device_busy"][dev] += exec_ms
        st["tasks_per_device"][dev] += 1
        stats["executed"] += exec_ms
        if fault_state is not None:
            fault_state["commits"].append((j, v, dev, worker, start, end, exec_ms))
        if collect_trace:
            st["trace"].append(dict(job=j, task=v, device=dev, worker=worker, start=start, end=end))
        policy.on_task_finish(j, v, dev, end)

        for e in dag.succs[v]:
            w = dag.edges[e][1]
            st["indeg"][w] -= 1
            st["ready_time"][w] = max(st["ready_time"][w], end)
            if st["indeg"][w] == 0:
                events.schedule(
                    (st["ready_time"][w], EV_READY, j, w, st["task_epoch"][w])
                )
        st["remaining"] -= 1
        if st["remaining"] == 0:
            complete_job(j)

    def requeue_job(jid, killed_tasks, t):
        """Mirror of EngineCore::requeue_job: recompute the ready
        frontier of a job after kills; epoch bumps invalidate stale
        ready events already in the heap."""
        refetch = fault_state["spec"]["refetch"] if fault_state is not None else 0.0
        st = jobs[jid]
        dag = st["dag"]
        was_complete = st["remaining"] == 0
        remaining = 0
        pushes = []
        for v in range(dag.node_count()):
            if st["assignments"][v] is not None:
                continue  # already executed and not killed
            remaining += 1
            indeg = 0
            ready = st["admit"]
            for e in dag.preds[v]:
                u = dag.edges[e][0]
                if st["assignments"][u] is None:
                    indeg += 1
                else:
                    ready = max(ready, st["finish"][u])
            st["ready_time"][v] = ready
            if v in killed_tasks:
                st["task_epoch"][v] += 1
                st["indeg"][v] = indeg
                if indeg == 0:
                    pushes.append((max(ready, t) + refetch, v, st["task_epoch"][v]))
            elif indeg != st["indeg"][v]:
                st["task_epoch"][v] += 1
                st["indeg"][v] = indeg
        st["remaining"] = remaining
        if was_complete and remaining > 0:
            # Revoke the pending drain: the job came back to life.
            st["drain_epoch"] += 1
            st["complete"] = 0.0
        for (at, v, ep) in pushes:
            events.schedule((at, EV_READY, jid, v, ep))

    def device_down(dev, drain, t):
        """Mirror of EngineCore::device_down: kill (or drain around)
        in-flight work on the victim, roll back coherence, requeue."""
        fs = fault_state
        stats["failures"] += 1
        if not fs["spec"]["scripted"]:
            down_ms = exp_mean_ms(fs["rng"], fs["spec"]["mttr"])
            events.schedule((t + down_ms, EV_UP, dev, 0, 0))
        else:
            (_, down_ms, _) = fs["scripted"][dev].popleft()
        up_at = t + down_ms
        dev_state[dev] = "draining" if drain else "down"
        if drain:
            return  # in-flight work runs to completion; only dispatch stops
        killed = []
        kept = []
        for c in fs["commits"]:
            if c[5] <= t:
                continue  # already retired
            if c[2] == dev:
                killed.append(c)
            else:
                kept.append(c)
        fs["commits"] = kept
        for (cj, cv, cd, cw, cs, ce, cx) in killed:
            st = jobs[cj]
            done = max(t - cs, 0.0)
            stats["wasted"] += done
            stats["executed"] -= cx - done
            stats["reexec"] += 1
            st["device_busy"][cd] -= cx
            st["tasks_per_device"][cd] -= 1
            st["finish"][cv] = 0.0
            st["assignments"][cv] = None
            mask_of[st["out"][cv]] = 0  # Directory::clear
            if collect_trace:
                st["trace"] = [ev for ev in st["trace"] if ev["task"] != cv]
            policy.on_task_killed(cj, cv)
        # Directory::invalidate_node: every replica on the dead memory
        # node is lost; sole copies fall back to the host checkpoint.
        bit = 1 << dev
        for h in range(len(mask_of)):
            if mask_of[h] & bit:
                mask_of[h] &= ~bit
                if mask_of[h] == 0:
                    mask_of[h] = 1
        for w in range(len(worker_free[dev])):
            worker_free[dev][w] = up_at
        affected = sorted({c[0] for c in killed})
        for jid in affected:
            requeue_job(jid, [c[1] for c in killed if c[0] == jid], t)
        stats["replans"] += policy.on_device_down(dev)

    def device_up(dev, t):
        dev_state[dev] = "up"
        for w in range(len(worker_free[dev])):
            worker_free[dev][w] = max(worker_free[dev][w], t)
        fs = fault_state
        if not fs["spec"]["scripted"]:
            events.schedule((t + exp_mean_ms(fs["rng"], fs["spec"]["mtbf"]), EV_DOWN, dev, 0, 0))
        stats["replans"] += policy.on_device_up(dev)

    while len(events):
        t, kind, j, v, heap_epoch = events.pop()
        stats["events"] += 1
        if kind == EV_DOWN:
            device_down(j, v == 1, t)
        elif kind == EV_UP:
            device_up(j, t)
        elif kind == EV_ARRIVAL:
            if adm.has_slot():
                memw["live_jobs"] += 1
                admit_job(j, t)
            else:
                budget = jobs[j]["budget"]
                if adm.predicts_reject(budget):
                    # Predictive rejection: the pending backlog alone
                    # already exceeds this job's wait budget.
                    st = jobs[j]
                    st["rejected"] = True
                    st["remaining"] = 0
                    st["admit"] = t
                    st["complete"] = t
                    state["completed"] += 1
                else:
                    st = jobs[j]
                    adm.push_pending(j, st["prio"], st["deadline_abs"], st["est_work"])
                    memw["live_jobs"] += 1
                    note_mem()
                    if budget != math.inf:
                        events.schedule((t + budget, EV_REJECT, j, 0, 0))
        elif kind == EV_DRAIN:
            if heap_epoch == jobs[j]["drain_epoch"]:
                adm.release_slot()
                state["completed"] += 1
                memw["live_jobs"] -= 1
                memw["live_tasks"] -= jobs[j]["dag"].node_count()
                memw["live_handles"] -= jobs[j]["_nhandles"]
                nxt = adm.pop_pending()
                if nxt is not None:
                    admit_job(nxt, t)
        elif kind == EV_REJECT:
            if adm.remove_pending(j):
                memw["live_jobs"] -= 1
                st = jobs[j]
                st["rejected"] = True
                st["remaining"] = 0
                st["admit"] = t
                st["complete"] = t
                state["completed"] += 1
        else:
            if heap_epoch == jobs[j]["task_epoch"][v]:
                dispatch(j, v, t)
        # Stop once every job resolved: fault clocks would otherwise
        # tick forever.
        if fault_state is not None and state["completed"] == len(jobs):
            break

    for j, st in enumerate(jobs):
        assert st["rejected"] or st["remaining"] == 0, f"job {j}: stuck"

    return [
        dict(
            makespan=0.0 if st["rejected"] else st["complete"] - st["submit"],
            submit=st["submit"],
            admit=st["admit"],
            complete=st["complete"],
            cls=st["cls"],
            prio=st["prio"],
            deadline_abs=st["deadline_abs"],
            rejected=st["rejected"],
            assignments=st["assignments"],
            ledger_count=st["ledger_count"],
            ledger_bytes=st["ledger_bytes"],
            tasks_per_device=st["tasks_per_device"],
            device_busy=st["device_busy"],
            trace=st["trace"],
        )
        for st in jobs
    ], stats


# ------------------------------------------ arrivals + queueing metrics

def fixed_times(rate_jps, n):
    return [i * (1000.0 / rate_jps) for i in range(n)]


def poisson_times(rate_jps, seed, n):
    rng = pm.Pcg32.seeded(seed)
    t = 0.0
    out = []
    for _ in range(n):
        t += -math.log(1.0 - rng.gen_f64()) * (1000.0 / rate_jps)
        out.append(t)
    return out


def bursty_times(rate_jps, burst, seed, n):
    rng = pm.Pcg32.seeded(seed)
    epoch_rate = rate_jps / burst
    t = 0.0
    out = []
    while len(out) < n:
        t += -math.log(1.0 - rng.gen_f64()) * (1000.0 / epoch_rate)
        for _ in range(burst):
            if len(out) == n:
                break
            out.append(t)
    return out


def percentile_nearest_rank(sorted_vals, p):
    rank = math.ceil(p / 100.0 * len(sorted_vals))
    rank = min(max(rank, 1), len(sorted_vals))
    return sorted_vals[rank - 1]


def deadline_hit(r):
    if r.get("deadline_abs", math.inf) == math.inf:
        return True
    return (not r.get("rejected", False)) and r["complete"] <= r["deadline_abs"] + 1e-9


def session_metrics(results, workers):
    # Latency metrics describe served traffic; rejected jobs are
    # excluded and counted separately (mirror of SessionReport).
    done = [r for r in results if not r.get("rejected", False)]
    sojourns = sorted(r["complete"] - r["submit"] for r in done)
    qdelays = [r["admit"] - r["submit"] for r in done]
    span = max((r["complete"] for r in results), default=0.0)
    busy = [0.0] * len(workers)
    for r in results:
        for d, b in enumerate(r["device_busy"]):
            busy[d] += b
    events = []
    for r in done:
        events.append((r["admit"], 1))
        events.append((r["complete"], -1))
    events.sort()
    cur = best = 0
    for _, delta in events:
        cur += delta
        best = max(best, cur)
    with_ddl = [r for r in results if r.get("deadline_abs", math.inf) != math.inf]
    return dict(
        span=span,
        p50=percentile_nearest_rank(sojourns, 50.0) if sojourns else 0.0,
        p95=percentile_nearest_rank(sojourns, 95.0) if sojourns else 0.0,
        p99=percentile_nearest_rank(sojourns, 99.0) if sojourns else 0.0,
        mean_sojourn=sum(sojourns) / len(sojourns) if sojourns else 0.0,
        mean_qdelay=sum(qdelays) / len(qdelays) if qdelays else 0.0,
        throughput=len(done) / (span / 1000.0) if span > 0 else 0.0,
        max_concurrent=best,
        rejected=len(results) - len(done),
        deadline_hit_rate=(
            sum(1 for r in with_ddl if deadline_hit(r)) / len(with_ddl)
            if with_ddl
            else 1.0
        ),
        utilization=[
            (b / (span * w) if span > 0 else 0.0) for b, w in zip(busy, workers)
        ],
    )


# ------------------------------------------ streaming quantiles (CKMS)
# Mirror of util::stats::CkmsSketch + sim::report::QuantileAcc (keep in
# sync): a deterministic Greenwald–Khanna summary with the CKMS uniform
# invariant g + delta <= max(floor(2*eps*n), 1). The report path keeps
# exact sojourns up to EXACT_SOJOURN_LIMIT completions — bit-identical
# to the sorted-vector path — and spills into the sketch beyond it.

EXACT_SOJOURN_LIMIT = 16384
SKETCH_EPS = 0.001


class CkmsSketch:
    def __init__(self, eps):
        assert 0.0 < eps < 0.5, f"eps must be in (0, 0.5), got {eps}"
        self.eps = eps
        self.tuples = []  # (value, g, delta), sorted by value
        self.n = 0
        self.unmerged = 0

    def _band(self):
        return max(int(2.0 * self.eps * self.n), 1)

    def insert(self, v):
        self.insert_weighted(v, 1)
        self.unmerged += 1
        if self.unmerged >= max(int(1.0 / (2.0 * self.eps)), 1):
            self.compress()
            self.unmerged = 0

    def insert_weighted(self, v, g):
        self.n += g
        # partition_point(|t| t.0 <= v): first index whose value > v.
        lo, hi = 0, len(self.tuples)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.tuples[mid][0] <= v:
                lo = mid + 1
            else:
                hi = mid
        delta = 0 if (lo == 0 or lo == len(self.tuples)) else max(self._band() - 1, 0)
        self.tuples.insert(lo, (v, g, delta))

    def compress(self):
        if len(self.tuples) < 2:
            return
        band = self._band()
        out = [self.tuples[-1]]
        for i in range(len(self.tuples) - 2, -1, -1):
            v, g, delta = self.tuples[i]
            nv, ng, ndelta = out[-1]
            if i != 0 and g + ng + ndelta <= band:
                out[-1] = (nv, g + ng, ndelta)
            else:
                out.append((v, g, delta))
        out.reverse()
        self.tuples = out

    def merge(self, other):
        for (v, g, _) in other.tuples:
            self.insert_weighted(v, g)
        self.compress()

    def query(self, p):
        assert 0.0 < p <= 100.0, f"p must be in (0, 100], got {p}"
        if self.n == 0:
            return 0.0
        target = math.ceil(p / 100.0 * self.n)
        budget = target + int(self.eps * self.n)
        rank = 0
        prev = self.tuples[0][0]
        for (v, g, delta) in self.tuples:
            if rank + g + delta > budget:
                return prev
            rank += g
            prev = v
        return self.tuples[-1][0]


class QuantileAcc:
    """Mirror of sim::report::QuantileAcc: exact below the spill
    threshold, eps-approximate beyond it."""

    def __init__(self):
        self.exact = []
        self.sketch = None

    def push(self, x):
        if self.sketch is not None:
            self.sketch.insert(x)
            return
        self.exact.append(x)
        if len(self.exact) > EXACT_SOJOURN_LIMIT:
            sk = CkmsSketch(SKETCH_EPS)
            for v in self.exact:
                sk.insert(v)
            self.exact = []
            self.sketch = sk

    def count(self):
        return self.sketch.n if self.sketch is not None else len(self.exact)

    def is_sketched(self):
        return self.sketch is not None

    def percentile(self, p):
        if self.sketch is not None:
            return self.sketch.query(p)
        if not self.exact:
            return 0.0
        return percentile_nearest_rank(sorted(self.exact), p)


def streaming_session_metrics(results, workers, max_concurrent=0):
    """Mirror of StreamingTally -> SessionReport scalar metrics: one
    fold pass with a QuantileAcc instead of the full sojourn vector.
    Below EXACT_SOJOURN_LIMIT completions this is bit-identical to
    session_metrics (pinned by run_checks); beyond it percentiles are
    eps-approximate. max_concurrent comes from the engine's
    stats["max_inflight"] — the interval sweep session_metrics runs
    needs every (admit, complete) pair, which streaming drops."""
    acc = QuantileAcc()
    completed = 0
    rejected = 0
    sum_sojourn = 0.0
    sum_delay = 0.0
    with_ddl = 0
    hits = 0
    span = 0.0
    busy = [0.0] * len(workers)
    for r in results:
        span = max(span, r["complete"])
        for d, b in enumerate(r["device_busy"]):
            busy[d] += b
        if r.get("deadline_abs", math.inf) != math.inf:
            with_ddl += 1
            if deadline_hit(r):
                hits += 1
        if r.get("rejected", False):
            rejected += 1
            continue
        completed += 1
        s = r["complete"] - r["submit"]
        acc.push(s)
        sum_sojourn += s
        sum_delay += r["admit"] - r["submit"]
    return dict(
        span=span,
        p50=acc.percentile(50.0),
        p95=acc.percentile(95.0),
        p99=acc.percentile(99.0),
        mean_sojourn=sum_sojourn / completed if completed else 0.0,
        mean_qdelay=sum_delay / completed if completed else 0.0,
        throughput=completed / (span / 1000.0) if span > 0 else 0.0,
        max_concurrent=max_concurrent,
        rejected=rejected,
        deadline_hit_rate=hits / with_ddl if with_ddl else 1.0,
        utilization=[
            (b / (span * w) if span > 0 else 0.0) for b, w in zip(busy, workers)
        ],
        sojourn_sketched=acc.is_sketched(),
    )


def class_metrics(results, span, n_classes, names):
    """Mirror of SessionReport::per_class."""
    out = []
    for c in range(n_classes):
        of_class = [r for r in results if r.get("cls", 0) == c]
        done = sorted(
            (r["complete"] - r["submit"] for r in of_class if not r.get("rejected", False))
        )
        with_ddl = [r for r in of_class if r.get("deadline_abs", math.inf) != math.inf]
        out.append(
            dict(
                name=names[c] if c < len(names) else f"class{c}",
                jobs=len(of_class),
                rejected=sum(1 for r in of_class if r.get("rejected", False)),
                p50=percentile_nearest_rank(done, 50.0) if done else 0.0,
                p95=percentile_nearest_rank(done, 95.0) if done else 0.0,
                p99=percentile_nearest_rank(done, 99.0) if done else 0.0,
                mean_sojourn=sum(done) / len(done) if done else 0.0,
                deadline_hit_rate=(
                    sum(1 for r in with_ddl if deadline_hit(r)) / len(with_ddl)
                    if with_ddl
                    else 1.0
                ),
                throughput=(len(done) / (span / 1000.0)) if span > 0 else 0.0,
            )
        )
    return out


def make_open_policy(spec, k, model, window=12):
    if spec == "eager":
        return OpenEager()
    if spec in ("dmda", "heft"):
        # heft's select rule is dmda's EFT estimator; ranks are untouched
        # by select, so the schedule coincides (as in the Rust engines).
        return OpenDmda()
    if spec == "gp":
        return OpenGp(k, model)
    if spec.startswith("gp:window"):
        # Mirror of registry::build_gp's param list, e.g.
        # "gp:window=64,incremental=0".
        params = dict(part.split("=", 1) for part in spec[3:].split(","))
        extra = set(params) - {"window", "incremental"}
        if extra:
            raise ValueError(f"unmirrored gp param(s): {sorted(extra)}")
        return OpenGpWindow(
            k,
            model,
            window=int(params["window"]),
            incremental=params.get("incremental", "1") != "0",
        )
    if spec == "cpu-only":
        return OpenPin(0)
    if spec == "gpu-only":
        return OpenPin(1)
    raise ValueError(spec)


def open_run(
    dags,
    spec,
    submits,
    queue,
    model=None,
    workers=None,
    collect_trace=False,
    qos=None,
    admit="fifo",
    stream_budget=math.inf,
    fault=None,
    equeue="heap",
):
    model = model or CalibratedModel()
    workers = workers or PAPER_WORKERS
    policy = make_open_policy(spec, len(workers), model)
    results, stats = simulate_open_engine(
        list(zip(dags, submits)),
        policy,
        workers,
        model,
        queue,
        collect_trace=collect_trace,
        qos=qos,
        admit=admit,
        stream_budget=stream_budget,
        fault=fault,
        equeue=equeue,
    )
    # Mirror of simulate_open_qos reading scheduler.replan_stats() into
    # SessionReport.replans / replan_cost_ms (zero for static policies).
    rs = getattr(policy, "rstats", None)
    stats["session_replans"] = rs["replans"] if rs else 0
    stats["replan_cost_ns"] = rs["cost_ns"] if rs else 0
    return results, policy, stats


# ----------------------------------------------------- QoS job classes

def default_qos_mix():
    """Mirror of workloads::default_qos_mix (keep in sync)."""
    return [
        dict(name="interactive", weight=3.0, family=("layered", 12, MA),
             size=256, prio=0, deadline=12.0, budget=8.0),
        dict(name="standard", weight=2.0, family=("layered", 24, MA),
             size=256, prio=0, deadline=30.0, budget=20.0),
        dict(name="batch", weight=1.0, family=("phased", 8, 4),
             size=256, prio=0, deadline=math.inf, budget=math.inf),
    ]


def build_family(family, size, seed):
    """Mirror of workloads::JobFamily::build."""
    kind = family[0]
    if kind == "phased":
        return phased(family[1], family[2], size)
    if kind == "layered":
        return generate_layered(scaled_gen_cfg(family[1], family[2], size, seed))
    if kind == "chain":
        return chain(family[1], family[2], size)
    raise ValueError(kind)


def job_classes(classes, n, seed):
    """Mirror of workloads::job_classes: one weighted gen_f64 pick plus
    one next_u64 DAG seed per job, PCG stream seed ^ 0x514F5321."""
    total = sum(c["weight"] for c in classes)
    rng = pm.Pcg32.seeded(seed ^ 0x514F5321)
    out = []
    for _ in range(n):
        x = rng.gen_f64() * total
        job_seed = rng.next_u64()
        idx = len(classes) - 1
        acc = 0.0
        for i, c in enumerate(classes):
            acc += c["weight"]
            if x < acc:
                idx = i
                break
        c = classes[idx]
        out.append(
            dict(
                dag=build_family(c["family"], c["size"], job_seed),
                qos=dict(cls=idx, prio=c["prio"], deadline=c["deadline"], budget=c["budget"]),
            )
        )
    return out


# Mirror of main.rs DEFAULT_FAULT ("fault:at=60:dev=1:down=40;refetch=2"):
# kill the GPU 60 ms into the burst for 40 ms, 2 ms re-fetch per retry.
DEFAULT_FAULT = dict(
    mtbf=math.inf, mttr=80.0, seed=9, refetch=2.0, scripted=[(60.0, 1, 40.0, False)]
)


# ------------------------------------------------- scenario replication
# Mirror of rust/src/scenario/ (keep in sync): declarative scenario
# files, derived per-repetition seeds, and the replication statistics
# (Welford mean/stddev + Student-t 95% CI) behind BENCH_scenarios.json.

# Mirror of util::stats::t95: exact df 1..=30, conventional steps after.
T95_TABLE = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
    2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
    2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
]


def t95(df):
    if df == 0:
        return math.inf
    if df <= 30:
        return T95_TABLE[df - 1]
    if df <= 40:
        return 2.021
    if df <= 60:
        return 2.000
    if df <= 120:
        return 1.980
    return 1.960


class Welford:
    """Mirror of util::stats::Welford (push + Chan merge)."""

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0

    def push(self, x):
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    def merge(self, other):
        if other.n == 0:
            return
        if self.n == 0:
            self.n, self.mean, self.m2 = other.n, other.mean, other.m2
            return
        n = self.n + other.n
        d = other.mean - self.mean
        mean = self.mean + d * (other.n / n)
        self.m2 = self.m2 + other.m2 + d * d * (self.n * other.n / n)
        self.n, self.mean = n, mean

    def variance(self):
        return 0.0 if self.n < 2 else self.m2 / (self.n - 1)

    def stddev(self):
        return math.sqrt(self.variance())

    def ci95_half_width(self):
        if self.n < 2:
            return 0.0
        return t95(self.n - 1) * self.stddev() / math.sqrt(self.n)


# Mirror of scenario::runner's seed derivation: repetition 0 keeps the
# base seeds verbatim; later reps open a PCG32 on a (rep, axis) stream.
REP_STREAM = 0x5C3AAB5E
WORKLOAD_AXIS, ARRIVAL_AXIS, FAULT_AXIS = 0, 1, 2


def rep_seed(base, rep, axis):
    if rep == 0:
        return base
    return pm.Pcg32(base, REP_STREAM ^ (rep << 8) ^ axis).next_u64()


def parse_raw_config(src):
    """Mirror of config::parse_raw: [section] / key = value / # comments;
    duplicate keys within a section are hard errors."""
    out = {}
    section = ""
    for lineno, raw in enumerate(src.splitlines()):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        if line.startswith("["):
            if not line.endswith("]"):
                raise ValueError(f"line {lineno + 1}: bad section header")
            section = line[1:-1].strip()
            out.setdefault(section, {})
            continue
        if "=" not in line:
            raise ValueError(f"line {lineno + 1}: expected key = value")
        k, v = line.split("=", 1)
        key = k.strip()
        sec = out.setdefault(section, {})
        if key in sec:
            raise ValueError(
                f"line {lineno + 1}: duplicate key {key!r} in section [{section}]"
            )
        sec[key] = v.strip().strip('"')
    return out


def _parse_params(src):
    out = {}
    for part in src.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"expected key=value, got {part!r}")
        k, v = part.split("=", 1)
        out[k.strip()] = v.strip()
    return out


def parse_stream_spec(spec):
    """Mirror of StreamConfig::from_spec (the subset scenarios use)."""
    s = spec.strip()
    if ":" in s:
        name, src = s.split(":", 1)
        if name.strip() != "stream":
            raise ValueError(f'stream spec must start with "stream:", got {spec!r}')
    elif s in ("stream", ""):
        src = ""
    else:
        src = s
    p = _parse_params(src)
    arrival = p.pop("arrival", "closed")
    queue = int(p.pop("queue", 32))
    if queue < 1:
        raise ValueError("queue must be >= 1")
    admit = p.pop("admit", "fifo")
    if admit not in ("fifo", "edf", "sjf", "reject"):
        raise ValueError(f"unknown admit {admit!r}")
    if admit != "fifo" and arrival == "closed":
        raise ValueError(f"admit={admit} requires timed arrivals")
    budget = float(p.pop("budget", math.inf)) if admit == "reject" else math.inf
    out = dict(arrival=arrival, queue=queue, admit=admit, budget=budget)
    if arrival in ("fixed", "poisson", "bursty"):
        out["rate"] = float(p.pop("rate"))
        if out["rate"] <= 0.0:
            raise ValueError(f"arrival={arrival} requires rate > 0")
    elif arrival != "closed":
        raise ValueError(f"unknown arrival {arrival!r}")
    if arrival in ("poisson", "bursty"):
        out["seed"] = int(p.pop("seed", 7))
    if arrival == "bursty":
        out["burst"] = int(p.pop("burst", 4))
    if p:
        raise ValueError(f"unknown stream keys {sorted(p)} in {spec!r}")
    return out


def _rust_num(v):
    """Rust {} Display for the f64s in spec strings: integral values
    print without the trailing .0 (220.0 -> \"220\")."""
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def stream_spec_string(st):
    """Mirror of StreamConfig::spec_string (canonical round-trip form)."""
    a = st["arrival"]
    if a == "closed":
        s = "stream:arrival=closed"
    elif a == "fixed":
        s = f"stream:arrival=fixed,rate={_rust_num(st['rate'])},queue={st['queue']}"
    elif a == "poisson":
        s = (
            f"stream:arrival=poisson,rate={_rust_num(st['rate'])},"
            f"queue={st['queue']},seed={st['seed']}"
        )
    else:
        s = (
            f"stream:arrival=bursty,rate={_rust_num(st['rate'])},burst={st['burst']},"
            f"queue={st['queue']},seed={st['seed']}"
        )
    if st["admit"] != "fifo":
        s += f",admit={st['admit']}"
    if math.isfinite(st["budget"]):
        s += f",budget={_rust_num(st['budget'])}"
    return s


def parse_fault_spec(spec):
    """Mirror of FaultSpec::from_spec -> the open_run fault dict."""
    s = spec.strip()
    if ":" in s:
        name, src = s.split(":", 1)
        if name.strip() != "fault":
            raise ValueError(f'fault spec must start with "fault:", got {spec!r}')
    elif s in ("fault", ""):
        src = ""
    else:
        src = s
    if "at=" in src:
        out = dict(mtbf=math.inf, mttr=80.0, seed=9, refetch=0.0, scripted=[])
        for group in src.split(";"):
            group = group.strip()
            if not group:
                raise ValueError("empty fault window (stray ';')")
            if group.startswith("refetch="):
                out["refetch"] = float(group[len("refetch="):])
                continue
            at = dev = down = None
            drain = False
            for kv in group.split(":"):
                k, v = kv.split("=", 1)
                k, v = k.strip(), v.strip()
                if k == "at":
                    at = float(v)
                elif k == "dev":
                    dev = int(v)
                elif k in ("down", "drain"):
                    drain = k == "drain"
                    down = float(v)
                else:
                    raise ValueError(f"unknown fault window key {k!r}")
            if at is None or dev is None or down is None:
                raise ValueError(f"incomplete fault window {group!r}")
            if dev == 0:
                raise ValueError("device 0 (host) cannot fail")
            out["scripted"].append((at, dev, down, drain))
        return out
    p = _parse_params(src)
    out = dict(
        mtbf=float(p.pop("mtbf", math.inf)),
        mttr=float(p.pop("mttr", 80.0)),
        seed=int(p.pop("seed", 9)),
        refetch=float(p.pop("refetch", 0.0)),
        scripted=[],
    )
    p.pop("dist", None)
    if p:
        raise ValueError(f"unknown fault keys {sorted(p)} in {spec!r}")
    return out


def fault_spec_string(f):
    """Mirror of FaultSpec::spec_string (scripted form only — the one
    scenarios commit; stochastic specs render their finite fields)."""
    if f["scripted"]:
        windows = ";".join(
            f"at={_rust_num(at)}:dev={dev}:{'drain' if drain else 'down'}={_rust_num(down)}"
            for at, dev, down, drain in f["scripted"]
        )
        s = f"fault:{windows}"
        if f["refetch"] > 0.0:
            s += f";refetch={_rust_num(f['refetch'])}"
        return s
    s = f"fault:mtbf={_rust_num(f['mtbf'])},mttr={_rust_num(f['mttr'])},seed={f['seed']}"
    if f["refetch"] > 0.0:
        s += f",refetch={_rust_num(f['refetch'])}"
    return s


_KERNELS = {"ma": MA, "mm": MM}


def parse_class_mix(spec):
    """Mirror of workloads::parse_class_mix (mirror family tuples)."""
    if spec.strip() == "default":
        return default_qos_mix()
    out = []
    for i, part in enumerate(spec.split(";")):
        part = part.strip()
        if not part:
            continue
        p = _parse_params(part)
        fam = p.pop("family", "layered")
        kernel = _KERNELS[p.pop("kernel", "ma")]
        if fam == "phased":
            family = ("phased", int(p.pop("width", 8)), int(p.pop("depth", 4)))
        elif fam == "layered":
            family = ("layered", int(p.pop("kernels", 24)), kernel)
        elif fam == "chain":
            family = ("chain", int(p.pop("len", 5)), kernel)
        else:
            raise ValueError(f"class {i}: unsupported family {fam!r} in the mirror")
        cls = dict(
            name=p.pop("name", f"class{i}"),
            weight=float(p.pop("weight", 1.0)),
            family=family,
            size=int(p.pop("size", 256)),
            prio=int(p.pop("prio", 0)),
            deadline=float(p.pop("deadline", math.inf)),
            budget=float(p.pop("budget", math.inf)),
        )
        if p:
            raise ValueError(f"class {i}: unknown keys {sorted(p)}")
        out.append(cls)
    if not out:
        raise ValueError(f"class mix {spec!r} defines no classes")
    return out


SCENARIO_SECTIONS = ("scenario", "platform", "workload", "stream", "fault", "sweep")


def _parse_axis(what, value, default):
    src = default if value is None else value
    out = []
    for part in src.split("|"):
        part = part.strip()
        if not part:
            raise ValueError(f"{what} axis has an empty entry in {src!r}")
        if part in out:
            raise ValueError(f"{what} axis repeats {part!r}")
        out.append(part)
    return out


def _take_section(raw, name, known):
    keys = dict(raw.get(name, {}))
    for k in keys:
        if k not in known:
            raise ValueError(f"unknown key {k!r} in [{name}]")
    return keys


def parse_scenario(src):
    """Mirror of scenario::ScenarioSpec::parse."""
    raw = parse_raw_config(src)
    for section in raw:
        if section == "":
            raise ValueError("scenario files have no top-level keys")
        if section not in SCENARIO_SECTIONS:
            raise ValueError(f"unknown section [{section}]")
    sc = _take_section(raw, "scenario", ("name", "jobs", "seed", "repetitions"))
    if "name" not in sc:
        raise ValueError("missing required [scenario] name")
    pl = _take_section(raw, "platform", ("kind",))
    kind = pl.get("kind", "paper")
    if kind not in ("paper", "tri"):
        raise ValueError(f"unknown [platform] kind {kind!r}")
    wl = _take_section(raw, "workload", ("classes",))
    fa = _take_section(raw, "fault", ("spec",))
    st = _take_section(raw, "stream", ("spec",))
    sw = _take_section(raw, "sweep", ("scheduler", "admit", "stream"))
    if "spec" in st and "stream" in sw:
        raise ValueError("[stream] spec and [sweep] stream are mutually exclusive")
    if "spec" in st:
        stream_axis = [st["spec"]]
    elif "stream" in sw:
        stream_axis = _parse_axis("sweep stream", sw["stream"], "")
    else:
        stream_axis = ["stream:arrival=closed"]
    for s in stream_axis:
        parse_stream_spec(s)
    spec = dict(
        name=sc["name"],
        jobs=int(sc.get("jobs", 24)),
        seed=int(sc.get("seed", 2015)),
        repetitions=int(sc.get("repetitions", 8)),
        tri=kind == "tri",
        classes=parse_class_mix(wl.get("classes", "default")),
        fault=parse_fault_spec(fa["spec"]) if "spec" in fa else None,
        scheduler_axis=_parse_axis("sweep scheduler", sw.get("scheduler"), "gp"),
        admit_axis=_parse_axis("sweep admit", sw.get("admit"), "fifo"),
        stream_axis=stream_axis,
    )
    if spec["jobs"] <= 0 or spec["repetitions"] <= 0:
        raise ValueError("[scenario] jobs and repetitions must be > 0")
    scenario_cells(spec)  # validate the sweep expands
    return spec


def _distinguishing_tokens(axis):
    token_sets = [[t.strip() for t in s.split(",")] for s in axis]
    out = []
    for i in range(len(axis)):
        own = [
            t
            for t in token_sets[i]
            if not all(j == i or t in token_sets[j] for j in range(len(axis)))
        ]
        out.append(",".join(own) if own else f"s{i}")
    return out


def scenario_cells(spec):
    """Mirror of ScenarioSpec::cells: (stream, scheduler, admit) order."""
    tags = _distinguishing_tokens(spec["stream_axis"])
    cells = []
    for si, base in enumerate(spec["stream_axis"]):
        for sched in spec["scheduler_axis"]:
            for admit in spec["admit_axis"]:
                if admit == "fifo":
                    sspec = base
                else:
                    if "admit=" in base:
                        raise ValueError(f"stream spec {base!r} already pins admit=")
                    sspec = f"{base},admit={admit}"
                label = sched
                if admit != "fifo" or len(spec["admit_axis"]) > 1:
                    label += f"+{admit}"
                if len(spec["stream_axis"]) > 1:
                    label += f"@{tags[si]}"
                cells.append(
                    dict(
                        label=label,
                        scheduler=sched,
                        admit=admit,
                        stream=parse_stream_spec(sspec),
                    )
                )
    return cells


def load_scenario(name_or_path):
    """Load a committed scenarios/NAME.toml (or an explicit path)."""
    path = name_or_path
    if not os.path.exists(path):
        path = os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "..", "..",
            "scenarios", f"{name_or_path}.toml",
        )
    with open(path) as fh:
        return parse_scenario(fh.read())


BUILTIN_SCENARIOS = [
    "open-poisson", "open-qos", "open-fault", "capacity-sweep", "engine-capacity",
]

# Mirror of sim::report::SCALAR_METRICS (same names, same order).
SCENARIO_METRICS = [
    "span_ms", "mean_sojourn_ms", "p50_sojourn_ms", "p95_sojourn_ms",
    "p99_sojourn_ms", "mean_queue_delay_ms", "throughput_jps", "goodput_jps",
    "deadline_hit_rate", "rejected_jobs", "max_concurrent_jobs",
    "replans", "replan_cost_ms",
]


def scenario_rep(spec, cell, rep, equeue="heap"):
    """Mirror of scenario::runner::run_repetition: one repetition of one
    sweep cell on seeds derived from (spec.seed, rep). equeue picks the
    event queue ("heap" | "ladder") — mirror of run_repetition_with; the
    reports are identical either way (pinned by run_checks)."""
    classed = job_classes(
        spec["classes"], spec["jobs"], rep_seed(spec["seed"], rep, WORKLOAD_AXIS)
    )
    dags = [j["dag"] for j in classed]
    qos = [j["qos"] for j in classed]
    st = cell["stream"]
    n = spec["jobs"]
    arrival = st["arrival"]
    if arrival == "fixed":
        submits = fixed_times(st["rate"], n)
    elif arrival == "poisson":
        submits = poisson_times(st["rate"], rep_seed(st["seed"], rep, ARRIVAL_AXIS), n)
    elif arrival == "bursty":
        submits = bursty_times(
            st["rate"], st["burst"], rep_seed(st["seed"], rep, ARRIVAL_AXIS), n
        )
    else:
        raise ValueError("closed-loop scenarios are not mirrored (builtins are open)")
    fault = spec["fault"]
    if fault is not None and not fault["scripted"]:
        # Scripted windows are the scenario's definition and replay
        # identically; only the stochastic trace re-derives its seed.
        fault = dict(fault, seed=rep_seed(fault["seed"], rep, FAULT_AXIS))
    model = CalibratedModel(tri=True) if spec["tri"] else CalibratedModel()
    workers = TRI_WORKERS if spec["tri"] else PAPER_WORKERS
    results, _, stats = open_run(
        dags, cell["scheduler"], submits, st["queue"],
        model=model, workers=workers, qos=qos, admit=st["admit"],
        stream_budget=st["budget"], fault=fault, equeue=equeue,
    )
    return results, stats, workers


def scenario_rep_metrics(spec, cell, rep):
    """One repetition reduced to the SCENARIO_METRICS dict plus the
    per-class rows (mirror of SessionReport::scalar_metrics)."""
    results, stats, workers = scenario_rep(spec, cell, rep)
    m = session_metrics(results, workers)
    useful = sum(sum(r["device_busy"]) for r in results)
    total = useful + stats["wasted"]
    goodput = m["throughput"] if total <= 0.0 else m["throughput"] * useful / total
    metrics = {
        "span_ms": m["span"],
        "mean_sojourn_ms": m["mean_sojourn"],
        "p50_sojourn_ms": m["p50"],
        "p95_sojourn_ms": m["p95"],
        "p99_sojourn_ms": m["p99"],
        "mean_queue_delay_ms": m["mean_qdelay"],
        "throughput_jps": m["throughput"],
        "goodput_jps": goodput,
        "deadline_hit_rate": m["deadline_hit_rate"],
        "rejected_jobs": float(m["rejected"]),
        "max_concurrent_jobs": float(m["max_concurrent"]),
        "replans": float(stats["session_replans"]),
        "replan_cost_ms": stats["replan_cost_ns"] / 1e6,
    }
    names = [c["name"] for c in spec["classes"]]
    classes = class_metrics(results, m["span"], len(names), names)
    return metrics, classes


def _stat(samples):
    w = Welford()
    for x in samples:
        w.push(x)
    return dict(n=w.n, mean=w.mean, std=w.stddev(), ci95=w.ci95_half_width())


def run_scenario_mirror(spec, repetitions=None):
    """Mirror of scenario::runner::run_scenario (serial; the Rust
    fan-out merges in repetition order, so the statistics agree)."""
    reps = max(repetitions or spec["repetitions"], 1)
    names = [c["name"] for c in spec["classes"]]
    cells_out = []
    for cell in scenario_cells(spec):
        per_rep = [scenario_rep_metrics(spec, cell, rep) for rep in range(reps)]
        metrics = {
            name: _stat([pr[0][name] for pr in per_rep]) for name in SCENARIO_METRICS
        }
        classes = []
        for ci, cname in enumerate(names):
            samples = [pr[1][ci] for pr in per_rep]
            classes.append(
                dict(
                    name=cname,
                    jobs=_stat([float(s["jobs"]) for s in samples]),
                    rejected=_stat([float(s["rejected"]) for s in samples]),
                    mean_sojourn_ms=_stat([s["mean_sojourn"] for s in samples]),
                    p95_sojourn_ms=_stat([s["p95"] for s in samples]),
                    deadline_hit_rate=_stat([s["deadline_hit_rate"] for s in samples]),
                    throughput_jps=_stat([s["throughput"] for s in samples]),
                )
            )
        cells_out.append(
            dict(
                label=cell["label"],
                scheduler=cell["scheduler"],
                stream=stream_spec_string(cell["stream"]),
                fault=fault_spec_string(spec["fault"]) if spec["fault"] else None,
                jobs=spec["jobs"],
                repetitions=reps,
                metrics=metrics,
                classes=classes,
            )
        )
    return dict(
        name=spec["name"],
        jobs=spec["jobs"],
        seed=spec["seed"],
        repetitions=reps,
        scheduler_axis=spec["scheduler_axis"],
        admit_axis=spec["admit_axis"],
        stream_axis=spec["stream_axis"],
        cells=cells_out,
    )


def scenarios_json(harness, reports):
    """Mirror of scenario::report::scenarios_json (same shape and
    indentation; floats via shortest-roundtrip repr)."""

    def esc(s):
        out = []
        for ch in s:
            if ch == "\\":
                out.append("\\\\")
            elif ch == '"':
                out.append('\\"')
            elif ord(ch) < 0x20:
                out.append(f"\\u{ord(ch):04x}")
            else:
                out.append(ch)
        return "".join(out)

    def stat_json(s):
        return (
            f'{{"n": {s["n"]}, "mean": {_rust_num(s["mean"])}, '
            f'"std": {_rust_num(s["std"])}, '
            f'"ci95_lo": {_rust_num(s["mean"] - s["ci95"])}, '
            f'"ci95_hi": {_rust_num(s["mean"] + s["ci95"])}}}'
        )

    def axis(values):
        return ", ".join(f'"{esc(v)}"' for v in values)

    lines = ["{", '  "bench": "scenarios",', f'  "harness": "{esc(harness)}",',
             '  "scenarios": [']
    for ri, rep in enumerate(reports):
        lines.append("    {")
        lines.append(f'      "name": "{esc(rep["name"])}",')
        lines.append(f'      "jobs": {rep["jobs"]},')
        lines.append(f'      "seed": {rep["seed"]},')
        lines.append(f'      "repetitions": {rep["repetitions"]},')
        lines.append(
            f'      "axes": {{"scheduler": [{axis(rep["scheduler_axis"])}], '
            f'"admit": [{axis(rep["admit_axis"])}], '
            f'"stream": [{axis(rep["stream_axis"])}]}},'
        )
        lines.append('      "cells": [')
        for ci, cell in enumerate(rep["cells"]):
            lines.append("        {")
            lines.append(f'          "label": "{esc(cell["label"])}",')
            lines.append(f'          "scheduler": "{esc(cell["scheduler"])}",')
            lines.append(f'          "stream": "{esc(cell["stream"])}",')
            if cell["fault"] is None:
                lines.append('          "fault": null,')
            else:
                lines.append(f'          "fault": "{esc(cell["fault"])}",')
            lines.append(f'          "jobs": {cell["jobs"]},')
            lines.append(f'          "repetitions": {cell["repetitions"]},')
            lines.append('          "metrics": {')
            for mi, name in enumerate(SCENARIO_METRICS):
                comma = "" if mi + 1 == len(SCENARIO_METRICS) else ","
                lines.append(
                    f'            "{name}": {stat_json(cell["metrics"][name])}{comma}'
                )
            lines.append("          },")
            lines.append('          "classes": [')
            for cli, cls in enumerate(cell["classes"]):
                comma = "" if cli + 1 == len(cell["classes"]) else ","
                lines.append(
                    f'            {{"name": "{esc(cls["name"])}", '
                    f'"jobs": {stat_json(cls["jobs"])}, '
                    f'"rejected": {stat_json(cls["rejected"])}, '
                    f'"mean_sojourn_ms": {stat_json(cls["mean_sojourn_ms"])}, '
                    f'"p95_sojourn_ms": {stat_json(cls["p95_sojourn_ms"])}, '
                    f'"deadline_hit_rate": {stat_json(cls["deadline_hit_rate"])}, '
                    f'"throughput_jps": {stat_json(cls["throughput_jps"])}}}{comma}'
                )
            lines.append("          ]")
            comma = "" if ci + 1 == len(rep["cells"]) else ","
            lines.append(f"        }}{comma}")
        lines.append("      ]")
        comma = "" if ri + 1 == len(reports) else ","
        lines.append(f"    }}{comma}")
    lines.append("  ]")
    lines.append("}")
    return "\n".join(lines) + "\n"


def bench_scenarios_json():
    reports = [run_scenario_mirror(load_scenario(n)) for n in BUILTIN_SCENARIOS]
    return scenarios_json("python-mirror", reports)


def bench_engine_json(jobs=20000):
    """Mirror of main.rs cmd_bench_engine / render_engine_json: the
    same chain template streamed through the engine under both event
    queues (the Rust default is a million jobs; 20k keeps the mirror
    quick while still spilling past EXACT_SOJOURN_LIMIT, so the
    sketched report path is what this bench exercises)."""
    import time

    model = CalibratedModel()
    workers = PAPER_WORKERS
    dag = chain(4, MM, 256)
    submits = fixed_times(400.0, jobs)
    rows = []
    for kind in ["heap", "ladder"]:
        t0 = time.perf_counter()
        results, _, stats = open_run(
            [dag] * jobs, "dmda", submits, 8, model=model, equeue=kind
        )
        wall = max(time.perf_counter() - t0, 1e-9)
        m = streaming_session_metrics(results, workers, stats["max_inflight"])
        rows.append((kind, wall, results, stats, m))
    lines = [
        "{",
        '  "bench": "engine",',
        '  "harness": "python-mirror",',
        f'  "jobs_submitted": {jobs},',
        '  "template": {"family": "chain", "len": 4, "kernel": "mm", "size": 256},',
        '  "scheduler": "dmda",',
        '  "stream": "stream:arrival=fixed,rate=400,queue=8",',
        '  "rows": [',
    ]
    for i, (kind, wall, results, stats, m) in enumerate(rows):
        comma = "" if i + 1 == len(rows) else ","
        completed = len(results) - m["rejected"]
        lines.append(
            f'    {{"queue_kind": "{kind}", "jobs_submitted": {len(results)}, '
            f'"jobs_completed": {completed}, "jobs_rejected": {m["rejected"]}, '
            f'"events_processed": {stats["events"]}, "wall_s": {wall:.6f}, '
            f'"events_per_sec": {stats["events"] / wall:.2f}, '
            f'"jobs_per_sec": {len(results) / wall:.2f}, '
            f'"mem_high_water_bytes": {stats["mem_high_water"]}, '
            f'"max_concurrent_jobs": {stats["max_inflight"]}, '
            f'"sojourn_sketched": {"true" if m["sojourn_sketched"] else "false"}, '
            f'"p50_sojourn_ms": {m["p50"]:.6f}, "p95_sojourn_ms": {m["p95"]:.6f}, '
            f'"p99_sojourn_ms": {m["p99"]:.6f}, "mean_sojourn_ms": {m["mean_sojourn"]:.6f}, '
            f'"mean_queue_delay_ms": {m["mean_qdelay"]:.6f}, "span_ms": {m["span"]:.6f}, '
            f'"throughput_jps": {m["throughput"]:.6f}}}{comma}'
        )
    lines.append("  ]")
    lines.append("}")
    return "\n".join(lines) + "\n"


# ----------------------------------------------------------------- checks

OK = True


def check(name, cond, detail=""):
    global OK
    mark = "ok" if cond else "FAIL"
    if not cond:
        OK = False
    print(f"  [{mark}] {name} {detail}")


def run_checks():
    model = CalibratedModel()

    print("engine sanity (pinned policies, structural counts)")
    d1 = chain(1, MA, 256)
    r = run(d1, "cpu-only")
    check("chain1 cpu-only zero transfers", r["ledger_count"] == 0)
    r = run(d1, "gpu-only")
    check("chain1 gpu-only 3 transfers", r["ledger_count"] == 3, r["ledger_count"])
    r = run(chain(5, MA, 256), "gpu-only")
    check("chain5 gpu-only 7 transfers", r["ledger_count"] == 7, r["ledger_count"])

    print("gp plan shapes (gp.rs tests)")
    gp2048 = Gp(generate_layered(paper_gen_cfg(MM, 2048)), 2, model)
    cpu_nodes = sum(1 for p in gp2048.parts if p == 0)
    check("mm 2048 pins to gpu", cpu_nodes <= 1, f"cpu={cpu_nodes}")
    check("mm 2048 ratio tiny", gp2048.ratios[0] < 0.02, f"{gp2048.ratios[0]:.4f}")
    gpma = Gp(generate_layered(paper_gen_cfg(MA, 2048)), 2, model)
    cpu_nodes = sum(1 for p in gpma.parts if p == 0)
    gpu_nodes = sum(1 for p in gpma.parts if p == 1)
    check("ma 2048 splits", cpu_nodes >= 2 and gpu_nodes > cpu_nodes, f"{cpu_nodes}/{gpu_nodes}")
    tri = CalibratedModel(tri=True)
    gptri = Gp(generate_layered(scaled_gen_cfg(200, MA, 2048, 5)), 3, tri)
    counts = [0, 0, 0]
    for p in gptri.parts:
        counts[p] += 1
    check("tri ma coverage", counts[1] > 0 and sum(1 for c in counts if c > 0) >= 2, counts)

    print("fig5/fig6 shapes (pipeline_integration)")
    for n in [512, 1024, 2048]:
        dag = generate_layered(paper_gen_cfg(MA, n))
        e = run(dag, "eager")["makespan"]
        d = run(dag, "dmda")["makespan"]
        g = run(dag, "gp")["makespan"]
        check(f"fig5 MA@{n} comparable", max(e, d, g) / min(e, d, g) < 2.0,
              f"{e:.2f} {d:.2f} {g:.2f}")
    for n in [512, 1024, 2048]:
        dag = generate_layered(paper_gen_cfg(MM, n))
        e = run(dag, "eager")["makespan"]
        d = run(dag, "dmda")["makespan"]
        g = run(dag, "gp")["makespan"]
        check(f"fig6 MM@{n} eager loses", e > 2.0 * g, f"{e:.2f} vs {g:.2f}")
        check(f"fig6 MM@{n} dmda~gp", abs(d - g) / g < 0.15, f"{d:.2f} vs {g:.2f}")
        if n == 1024:
            check("eager_slower_than_dmda (engine test)", e > 1.5 * d, f"{e:.2f} vs {d:.2f}")

    print("transfer shapes")
    dag = generate_layered(paper_gen_cfg(MA, 1024))
    e = run(dag, "eager")["ledger_count"]
    d = run(dag, "dmda")["ledger_count"]
    g = run(dag, "gp")["ledger_count"]
    check("ma 1024 gp minimizes transfers", e > d >= g, f"e={e} d={d} g={g}")
    totals = [0, 0, 0]
    for n in [256, 512, 1024, 2048]:
        dag = generate_layered(paper_gen_cfg(MA, n))
        for i, name in enumerate(["eager", "dmda", "gp"]):
            totals[i] += run(dag, name)["ledger_count"]
    check("sweep gp < eager", totals[2] < totals[0], totals)
    check("sweep gp < dmda", totals[2] < totals[1], totals)
    dag = generate_layered(paper_gen_cfg(MM, 2048))
    check("mm 2048 gp cpu<=1 tasks", run(dag, "gp")["tasks_per_device"][0] <= 1)
    check("mm 2048 dmda cpu==0 tasks", run(dag, "dmda")["tasks_per_device"][0] == 0)

    print("dual copy engines / prefetch / channels (engine tests)")
    dag = generate_layered(paper_gen_cfg(MA, 1024))
    for name in ["gp", "gpu-only"]:
        b = run(dag, name)
        du = run(dag, name, bus_channels=2)
        check(f"{name} dual no regress", du["makespan"] <= b["makespan"] + 1e-9)
        check(f"{name} dual same transfers", du["ledger_count"] == b["ledger_count"])
        check(f"{name} dual same pins", du["assignments"] == b["assignments"])
    b = run(dag, "gp")
    du = run(dag, "gp", bus_channels=2)
    check("gp MA dual helps >5%", du["makespan"] < 0.95 * b["makespan"],
          f"{du['makespan']:.2f} vs {b['makespan']:.2f}")
    for kernel in [MA, MM]:
        dag_k = generate_layered(paper_gen_cfg(kernel, 1024))
        for name in ["gp", "gpu-only", "cpu-only"]:
            b = run(dag_k, name)
            p = run(dag_k, name, prefetch=True)
            check(f"prefetch never hurts {name}/{kernel}", p["makespan"] <= b["makespan"] + 1e-9)
    dag = generate_layered(paper_gen_cfg(MA, 512))
    a = run(dag, "gp", bus_channels=64)["makespan"]
    b = run(dag, "gp", bus_channels=128)["makespan"]
    check("extra channels bounded", abs(a - b) < 1e-9)

    print("virtual source (engine test)")
    cfg = paper_gen_cfg(MA, 512)
    cfg["source"] = True
    dag = generate_layered(cfg)
    r = run(dag, "dmda")
    src = next(v for v, (name, _, _) in enumerate(dag.nodes) if name == "__source")
    check("source on host", r["assignments"][src] == 0)
    check("38 real kernels on workers", sum(r["tasks_per_device"]) == 38)

    print("tri-device pipeline (pipeline_integration)")
    dag = generate_layered(scaled_gen_cfg(120, MA, 1024, 3))
    tri = CalibratedModel(tri=True)
    for name in ["eager", "dmda", "gp"]:
        r = run(dag, name, model=tri, workers=TRI_WORKERS)
        check(f"tri {name} all assigned", sum(r["tasks_per_device"]) == 120,
              r["tasks_per_device"])

    print("gp seed-corpus cut quality (adaptive EXACT_GAIN satellite)")
    for kernel, n, bound in [(MA, 1024, None), (MA, 2048, None), (MM, 512, None)]:
        dag = generate_layered(paper_gen_cfg(kernel, n))
        gp = Gp(dag, 2, model)
        cut = gp.result["edge_cut"]
        tot = sum(gp.result["part_weights"])
        print(f"    gp {kernel}@{n}: cut={cut}us weights={gp.result['part_weights']}")

    print("windowed gp on the phased workload (acceptance headline)")
    best = None
    for window in [8, 12, 16]:
        dag = phased(8, 4, 256)
        one = run(dag, "gp")
        win = run(dag, "gp-window", window=window)
        gain = (one["makespan"] - win["makespan"]) / one["makespan"]
        replans = win["policy"].replans
        print(
            f"    window={window}: gp {one['makespan']:.2f} ms vs gp-window "
            f"{win['makespan']:.2f} ms ({gain * 100:+.1f}%, {replans} replans)"
        )
        if best is None or win["makespan"] < best:
            best = win["makespan"]
    check("gp-window beats gp on phased", best < one["makespan"], f"{best:.2f} vs {one['makespan']:.2f}")

    print("open engine: single-job equivalence (unified core vs closed engine)")
    cases = [
        (generate_layered(paper_gen_cfg(MA, 1024)), ["eager", "dmda", "gp", "gpu-only"]),
        (generate_layered(paper_gen_cfg(MM, 1024)), ["eager", "dmda", "gp"]),
        (phased(8, 4, 256), ["dmda", "gp"]),
        (chain(5, MA, 256), ["gpu-only", "cpu-only"]),
    ]
    for dag, names in cases:
        for nm in names:
            ref = run(dag, nm)
            got = open_run([dag], nm, [0.0], 1)[0][0]
            check(
                f"single {nm} n={dag.node_count()} exact",
                got["assignments"] == ref["assignments"]
                and got["ledger_count"] == ref["ledger_count"]
                and got["makespan"] == ref["makespan"],
                f"{got['makespan']:.6f} vs {ref['makespan']:.6f}",
            )

    print("open engine: single-job gp-window equivalence")
    dag = phased(8, 4, 256)
    ref = run(dag, "gp-window", window=12)
    got = open_run([dag], "gp:window=12", [0.0], 1)[0][0]
    check(
        "gp:window=12 single-job exact",
        got["assignments"] == ref["assignments"] and got["makespan"] == ref["makespan"],
        f"{got['makespan']:.6f} vs {ref['makespan']:.6f}",
    )

    print("open engine: poisson concurrency + determinism (default bench scenario)")
    jobs = [phased(8, 4, 256) for _ in range(24)]
    submits = poisson_times(220.0, 7, 24)
    for nm in ["dmda", "gp"]:
        results, _, _ = open_run(jobs, nm, submits, 8, collect_trace=True)
        m = session_metrics(results, PAPER_WORKERS)
        overlap = False
        spans = [(min(e["start"] for e in r["trace"]), max(e["end"] for e in r["trace"]))
                 for r in results]
        for i in range(len(spans)):
            for j2 in range(i + 1, len(spans)):
                if spans[i][0] < spans[j2][1] and spans[j2][0] < spans[i][1]:
                    overlap = True
        check(f"{nm} >=2 jobs overlap (trace)", overlap and m["max_concurrent"] >= 2,
              f"maxconc={m['max_concurrent']}")
        again, _, _ = open_run(jobs, nm, submits, 8, collect_trace=True)
        check(f"{nm} deterministic", [r["trace"] for r in again] == [r["trace"] for r in results])
        check(f"{nm} timings sane",
              all(r["admit"] >= r["submit"] and r["complete"] >= r["admit"] for r in results))

    print("open engine: queue=1 serializes and queues")
    results, _, _ = open_run(jobs[:8], "dmda", poisson_times(400.0, 7, 8), 1)
    m = session_metrics(results, PAPER_WORKERS)
    check("queue=1 max concurrent == 1", m["max_concurrent"] == 1, m["max_concurrent"])
    check("queue=1 positive queueing delay", m["mean_qdelay"] > 0.0,
          f"{m['mean_qdelay']:.3f} ms")

    print("open engine: cross-job gp-window vs per-job gp (mean sojourn)")
    win_found = False
    for rate in [120.0, 180.0, 220.0, 300.0]:
        submits = poisson_times(rate, 7, 24)
        gp_res, _, _ = open_run(jobs, "gp", submits, 8)
        win_res, _, _ = open_run(jobs, "gp:window=12", submits, 8)
        gp_m = session_metrics(gp_res, PAPER_WORKERS)
        win_m = session_metrics(win_res, PAPER_WORKERS)
        gain = (gp_m["mean_sojourn"] - win_m["mean_sojourn"]) / gp_m["mean_sojourn"]
        print(
            f"    rate={rate:.0f}: gp mean sojourn {gp_m['mean_sojourn']:.2f} ms vs "
            f"gp:window=12 {win_m['mean_sojourn']:.2f} ms ({gain * 100:+.1f}%)"
        )
        if rate == 220.0 and win_m["mean_sojourn"] < gp_m["mean_sojourn"]:
            win_found = True
    check("cross-job window wins at rate=220", win_found)

    print("QoS: admit=fifo with deadline-free tags is the pre-QoS engine bit-for-bit")
    # Finite deadlines now steer dmda's device choice (least-slack
    # dispatch), so bit-identity holds for deadline-free tags only.
    mix = default_qos_mix()
    classed = job_classes(mix, 24, 2015)
    qdags = [j["dag"] for j in classed]
    qqos = [j["qos"] for j in classed]
    qsubmits = bursty_times(380.0, 8, 7, 24)
    free_qos = [dict(q, deadline=math.inf) for q in qqos]
    plain, _, _ = open_run(qdags, "dmda", qsubmits, 2)
    tagged, _, _ = open_run(qdags, "dmda", qsubmits, 2, qos=free_qos, admit="fifo")
    check(
        "fifo ignores deadline-free qos (same schedule)",
        all(
            a["admit"] == b["admit"] and a["complete"] == b["complete"]
            and a["assignments"] == b["assignments"]
            for a, b in zip(plain, tagged)
        ),
    )

    print("QoS: edf/sjf pending-queue order (5-job table test)")
    # queue=1, arrivals at 0/.01/.02/.03/.04 ms while job 0 runs ~5 ms:
    # jobs 1..4 all pend; admissions then pop in key order.
    tdags = [phased(8, 4, 256)] + [chain(3, MA, 256) for _ in range(4)]
    tsub = [i * 0.01 for i in range(5)]
    tqos = [default_qos()]
    for i, (ddl, work_len) in enumerate([(100.0, 2), (50.0, 4), (80.0, 6), (20.0, 8)]):
        tqos.append(dict(cls=0, prio=0, deadline=ddl, budget=math.inf))
        tdags[1 + i] = chain(work_len, MA, 256)
    res, _, _ = open_run(tdags, "dmda", tsub, 1, qos=tqos, admit="edf")
    order = sorted(range(1, 5), key=lambda j: res[j]["admit"])
    check("edf order = deadline order", order == [4, 2, 3, 1], order)
    res, _, _ = open_run(tdags, "dmda", tsub, 1, qos=tqos, admit="sjf")
    order = sorted(range(1, 5), key=lambda j: res[j]["admit"])
    check("sjf order = est-work order", order == [1, 2, 3, 4], order)
    # Priority bands dominate both keys.
    pqos = list(tqos)
    pqos[4] = dict(cls=0, prio=1, deadline=20.0, budget=math.inf)
    res, _, _ = open_run(tdags, "dmda", tsub, 1, qos=pqos, admit="edf")
    order = sorted(range(1, 5), key=lambda j: res[j]["admit"])
    check("edf priority bands first", order == [2, 3, 1, 4], order)

    print("QoS: reject never admits past its budget (property)")
    rng = pm.Pcg32.seeded(0xB7D6E7)
    ok_budget = True
    saw_reject = 0
    for _ in range(12):
        nn = 12 + rng.gen_range(12)
        budgets = [rng.gen_f64() * 10.0 for _ in range(nn)]
        pqos = [dict(cls=0, prio=0, deadline=math.inf, budget=b) for b in budgets]
        pdags = [chain(2 + rng.gen_range(6), MA, 256) for _ in range(nn)]
        psub = bursty_times(300.0 + rng.gen_f64() * 400.0, 6, rng.next_u64(), nn)
        res, _, _ = open_run(pdags, "dmda", psub, 1 + rng.gen_range(2), qos=pqos, admit="reject")
        for r, b in zip(res, budgets):
            if r["rejected"]:
                saw_reject += 1
            elif r["admit"] - r["submit"] > b + 1e-9:
                ok_budget = False
    check("admitted waits within budgets", ok_budget)
    check("rejections occur across trials", saw_reject > 0, saw_reject)
    # Session-wide budget (admit=reject,budget=MS) caps jobs whose own
    # budget is infinite — mirror of StreamConfig::effective_budget_ms.
    sdags = [chain(4, MA, 256) for _ in range(12)]
    ssub = bursty_times(400.0, 6, 9, 12)
    sqos = [default_qos() for _ in range(12)]
    res, _, _ = open_run(sdags, "dmda", ssub, 1, qos=sqos, admit="reject", stream_budget=1.0)
    check(
        "stream budget caps default-qos waits",
        all(r["rejected"] or r["admit"] - r["submit"] <= 1.0 + 1e-9 for r in res),
    )
    check("stream budget causes rejections", any(r["rejected"] for r in res),
          sum(r["rejected"] for r in res))

    print("QoS: open-qos headline (bursty 380/s, burst 8, queue 2)")
    rows = {}
    for adm in ["fifo", "edf", "sjf", "reject"]:
        res, _, _ = open_run(qdags, "dmda", qsubmits, 2, qos=qqos, admit=adm)
        rows[adm] = session_metrics(res, PAPER_WORKERS)
        per = class_metrics(res, rows[adm]["span"], len(mix), [c["name"] for c in mix])
        print(
            f"    {adm:>6}: hit={rows[adm]['deadline_hit_rate']:.2f} "
            f"mean={rows[adm]['mean_sojourn']:.2f} ms p95={rows[adm]['p95']:.2f} "
            f"rej={rows[adm]['rejected']} "
            f"interactive(p95={per[0]['p95']:.2f}, hit={per[0]['deadline_hit_rate']:.2f})"
        )
    check(
        "edf beats fifo on deadline-hit",
        rows["edf"]["deadline_hit_rate"] >= rows["fifo"]["deadline_hit_rate"] + 0.15,
        f"{rows['edf']['deadline_hit_rate']:.2f} vs {rows['fifo']['deadline_hit_rate']:.2f}",
    )
    check(
        "sjf beats fifo on mean sojourn",
        rows["sjf"]["mean_sojourn"] < 0.85 * rows["fifo"]["mean_sojourn"],
        f"{rows['sjf']['mean_sojourn']:.2f} vs {rows['fifo']['mean_sojourn']:.2f}",
    )
    check("reject sheds load", rows["reject"]["rejected"] > 0, rows["reject"]["rejected"])

    print("QoS: classed stream determinism")
    c2 = job_classes(mix, 24, 2015)
    check(
        "job_classes deterministic",
        [j["qos"] for j in c2] == [j["qos"] for j in classed]
        and all(
            a["dag"].nodes == b["dag"].nodes and a["dag"].edges == b["dag"].edges
            for a, b in zip(c2, classed)
        ),
    )
    r1, _, _ = open_run(qdags, "dmda", qsubmits, 2, qos=qqos, admit="reject", collect_trace=True)
    r2, _, _ = open_run(qdags, "dmda", qsubmits, 2, qos=qqos, admit="reject", collect_trace=True)
    check(
        "open-qos scenario deterministic",
        [r["trace"] for r in r1] == [r["trace"] for r in r2]
        and [r["rejected"] for r in r1] == [r["rejected"] for r in r2]
        and [r["complete"] for r in r1] == [r["complete"] for r in r2],
    )

    print("faults: inert spec is the failure-free engine bit-for-bit")
    fjobs = [phased(8, 4, 256) for _ in range(24)]
    fsubmits = poisson_times(220.0, 7, 24)
    inert = dict(mtbf=math.inf, mttr=80.0, seed=9, refetch=0.0, scripted=[])
    base, _, base_stats = open_run(fjobs, "dmda", fsubmits, 8, collect_trace=True)
    same, _, inert_stats = open_run(fjobs, "dmda", fsubmits, 8, collect_trace=True, fault=inert)
    check(
        "mtbf=inf bit-identical",
        [r["trace"] for r in base] == [r["trace"] for r in same]
        and [r["complete"] for r in base] == [r["complete"] for r in same],
    )
    check(
        "inert recovery stats all zero",
        inert_stats["failures"] == 0 and inert_stats["reexec"] == 0
        and inert_stats["wasted"] == 0.0 and inert_stats["replans"] == 0,
    )
    check(
        "executed matches useful when failure-free",
        abs(base_stats["executed"] - sum(sum(r["device_busy"]) for r in base)) < 1e-6,
    )

    print("faults: stochastic injection is seed-deterministic")
    sf = dict(mtbf=120.0, mttr=40.0, seed=9, refetch=2.0, scripted=[])
    r1, _, s1 = open_run(fjobs, "dmda", fsubmits, 8, collect_trace=True, fault=sf)
    r2, _, s2 = open_run(fjobs, "dmda", fsubmits, 8, collect_trace=True, fault=sf)
    check(
        "fixed seed reproduces traces + stats",
        [r["trace"] for r in r1] == [r["trace"] for r in r2] and s1 == s2,
    )
    check("stochastic faults fire", s1["failures"] > 0, s1["failures"])
    check("stochastic all jobs complete", all(not r["rejected"] for r in r1))
    sf2 = dict(sf, seed=10)
    _, _, s3 = open_run(fjobs, "dmda", fsubmits, 8, fault=sf2)
    check("different seed, different schedule", s3 != s1)

    print("faults: scripted GPU kill mid-burst (accounting balance)")
    kres, _, ks = open_run(fjobs, "dmda", fsubmits, 8, fault=DEFAULT_FAULT)
    useful = sum(sum(r["device_busy"]) for r in kres)
    check("one failure injected", ks["failures"] == 1, ks["failures"])
    check("tasks re-executed", ks["reexec"] >= 1, ks["reexec"])
    check("wasted work positive", ks["wasted"] > 0.0, f"{ks['wasted']:.3f}")
    check(
        "executed == useful + wasted",
        abs(ks["executed"] - (useful + ks["wasted"])) < 1e-6,
        f"{ks['executed']:.6f} vs {useful + ks['wasted']:.6f}",
    )
    check("all jobs complete despite the kill", all(not r["rejected"] for r in kres))

    print("faults: drain stops dispatch without killing")
    df = dict(mtbf=math.inf, mttr=80.0, seed=9, refetch=0.0, scripted=[(0.0, 1, 50.0, True)])
    dres, _, ds = open_run(fjobs, "dmda", fsubmits, 8, collect_trace=True, fault=df)
    check(
        "no gpu dispatch during the drain window",
        all(ev["start"] >= 50.0 for r in dres for ev in r["trace"] if ev["device"] == 1),
    )
    check("drain kills nothing", ds["reexec"] == 0 and ds["wasted"] == 0.0)
    check("drain counts as one injected event", ds["failures"] == 1, ds["failures"])

    print("faults: gp:window recovery replanning vs one-shot gp re-dispatch")
    gp_res, _, gp_s = open_run(fjobs, "gp", fsubmits, 8, fault=DEFAULT_FAULT)
    win_res, _, win_s = open_run(fjobs, "gp:window=12", fsubmits, 8, fault=DEFAULT_FAULT)
    gp_m = session_metrics(gp_res, PAPER_WORKERS)
    win_m = session_metrics(win_res, PAPER_WORKERS)
    print(
        f"    gp mean sojourn {gp_m['mean_sojourn']:.2f} ms vs gp:window=12 "
        f"{win_m['mean_sojourn']:.2f} ms (replans {win_s['replans']})"
    )
    check(
        "recovery replanning beats naive re-dispatch (>3% sojourn)",
        win_m["mean_sojourn"] < 0.97 * gp_m["mean_sojourn"],
        f"{win_m['mean_sojourn']:.2f} vs {gp_m['mean_sojourn']:.2f}",
    )
    check("gp:window fired recovery replans", win_s["replans"] >= 1, win_s["replans"])
    check("one-shot gp never replans", gp_s["replans"] == 0, gp_s["replans"])

    print("percentiles (nearest rank)")
    hundred = [float(x) for x in range(1, 101)]
    check("p50 of 1..100 == 50", percentile_nearest_rank(hundred, 50.0) == 50.0)
    check("p95 of 1..100 == 95", percentile_nearest_rank(hundred, 95.0) == 95.0)
    check("p99 of 1..100 == 99", percentile_nearest_rank(hundred, 99.0) == 99.0)
    check("p50 of [4,6,10] == 6", percentile_nearest_rank([4.0, 6.0, 10.0], 50.0) == 6.0)

    print("scenario stats (Welford + Student-t, mirror of util::stats)")
    check("t95 anchors", t95(1) == 12.706 and t95(19) == 2.093 and t95(1000) == 1.960)
    check("t95 monotone", all(t95(df + 1) <= t95(df) for df in range(1, 200)))
    xs = [((i * 37 + 11) % 17) * 0.75 for i in range(40)]
    seq = Welford()
    for x in xs:
        seq.push(x)
    wa, wb = Welford(), Welford()
    for x in xs[:13]:
        wa.push(x)
    for x in xs[13:]:
        wb.push(x)
    wa.merge(wb)
    check(
        "welford merge == sequential",
        wa.n == seq.n
        and abs(wa.mean - seq.mean) < 1e-9
        and abs(wa.variance() - seq.variance()) < 1e-9,
    )
    one = Welford()
    one.push(7.25)
    check("one sample has no error bar", one.stddev() == 0.0 and one.ci95_half_width() == 0.0)

    print("scenario files (mirror of rust/src/scenario)")
    specs = {name: load_scenario(name) for name in BUILTIN_SCENARIOS}
    counts = {n: len(scenario_cells(s)) for n, s in specs.items()}
    check(
        "builtin sweep cell counts 7/4/3/6/2",
        counts
        == {
            "open-poisson": 7,
            "open-qos": 4,
            "open-fault": 3,
            "capacity-sweep": 6,
            "engine-capacity": 2,
        },
        counts,
    )
    check(
        "declared names match file names",
        all(s["name"] == n for n, s in specs.items()),
    )
    check(
        "committed repetitions support CIs",
        all(s["repetitions"] >= 2 for s in specs.values()),
    )
    # Rep 0 returns the base on every axis (by design), so uniqueness
    # is claimed across the base plus every derived (rep >= 1) seed.
    seeds = {2015} | {rep_seed(2015, r, a) for r in range(1, 8) for a in range(3)}
    check("derived rep seeds never collide", len(seeds) == 22, len(seeds))
    check("rep 0 keeps base seeds verbatim", rep_seed(2015, 0, FAULT_AXIS) == 2015)
    for bad in ["[scenario]\nname = t\n[warp]\nx = 1\n",
                "[scenario]\nname = t\nrepetitons = 3\n",
                "[scenario]\nname = a\nname = b\n"]:
        try:
            parse_scenario(bad)
            check(f"loud parse error for {bad.splitlines()[-1]!r}", False)
        except ValueError:
            check(f"loud parse error for {bad.splitlines()[-1]!r}", True)

    print("scenario rep 0 reproduces the hard-coded bench runs")
    sc_poisson = specs["open-poisson"]
    open_dags = [phased(8, 4, 256) for _ in range(24)]
    open_submits = poisson_times(220.0, 7, 24)
    for cell in scenario_cells(sc_poisson):
        old, _, _ = open_run(open_dags, cell["scheduler"], open_submits, 8, model=model)
        old_m = session_metrics(old, PAPER_WORKERS)
        new_m, _ = scenario_rep_metrics(sc_poisson, cell, 0)
        check(
            f"open-poisson {cell['label']} rep0 bit-identical",
            new_m["mean_sojourn_ms"] == old_m["mean_sojourn"]
            and new_m["span_ms"] == old_m["span"]
            and new_m["p95_sojourn_ms"] == old_m["p95"],
        )
    sc_fault = specs["open-fault"]
    check(
        "open-fault carries the scripted kill",
        sc_fault["fault"] == DEFAULT_FAULT
        and fault_spec_string(sc_fault["fault"]) == "fault:at=60:dev=1:down=40;refetch=2",
    )
    old, _, _ = open_run(
        open_dags, "gp", open_submits, 8, model=model, fault=DEFAULT_FAULT
    )
    old_m = session_metrics(old, PAPER_WORKERS)
    new_m, _ = scenario_rep_metrics(sc_fault, scenario_cells(sc_fault)[1], 0)
    check(
        "open-fault gp rep0 bit-identical",
        new_m["mean_sojourn_ms"] == old_m["mean_sojourn"]
        and new_m["span_ms"] == old_m["span"],
    )
    sc_qos = specs["open-qos"]
    qmix = default_qos_mix()
    qclassed = job_classes(qmix, 24, 2015)
    qsubmits = bursty_times(380.0, 8, 7, 24)
    for cell in scenario_cells(sc_qos)[:2]:  # fifo + edf
        old, _, _ = open_run(
            [j["dag"] for j in qclassed], "dmda", qsubmits, 2, model=model,
            qos=[j["qos"] for j in qclassed], admit=cell["admit"],
        )
        old_m = session_metrics(old, PAPER_WORKERS)
        new_m, _ = scenario_rep_metrics(sc_qos, cell, 0)
        check(
            f"open-qos {cell['label']} rep0 bit-identical",
            new_m["deadline_hit_rate"] == old_m["deadline_hit_rate"]
            and new_m["mean_sojourn_ms"] == old_m["mean_sojourn"],
        )
    r0, _ = scenario_rep_metrics(sc_poisson, scenario_cells(sc_poisson)[1], 0)
    r1, _ = scenario_rep_metrics(sc_poisson, scenario_cells(sc_poisson)[1], 1)
    check(
        "repetitions actually vary",
        r0["mean_sojourn_ms"] != r1["mean_sojourn_ms"],
    )

    print("scenario replication: fifo vs edf CIs disjoint at 20 reps")
    qos_report = run_scenario_mirror(sc_qos)
    cells = {c["label"]: c for c in qos_report["cells"]}
    fifo = cells["dmda+fifo"]["metrics"]["deadline_hit_rate"]
    edf = cells["dmda+edf"]["metrics"]["deadline_hit_rate"]
    check("committed open-qos runs 20 reps", qos_report["repetitions"] == 20)
    check("edf beats fifo on deadline hits", edf["mean"] > fifo["mean"],
          f"{edf['mean']:.3f} vs {fifo['mean']:.3f}")
    check(
        "fifo/edf 95% CIs disjoint (headline significant)",
        fifo["mean"] + fifo["ci95"] < edf["mean"] - edf["ci95"],
        f"fifo hi {fifo['mean'] + fifo['ci95']:.4f} vs edf lo {edf['mean'] - edf['ci95']:.4f}",
    )
    check(
        "every cell merges 3 classes over 20 reps",
        all(
            len(c["classes"]) == 3
            and all(s["n"] == 20 for m in c["metrics"].values() for s in [m])
            for c in qos_report["cells"]
        ),
    )

    import bisect

    print("event queue: ladder pops the heap's exact total order")
    qrng = pm.Pcg32.seeded(99)
    hq, lq = HeapQueue(), LadderQueue()
    last = 0.0
    scheduled = popped = 0
    mismatch = False
    for _ in range(2000):
        for _ in range(1 + qrng.next_u64() % 4):
            # Ties included: every ~8th event lands exactly on `last`.
            t = last if qrng.next_u64() % 8 == 0 else last + qrng.gen_f64() * 50.0
            ev = (t, int(qrng.next_u64() % 6), int(qrng.next_u64() % 64), 0, 0)
            hq.schedule(ev)
            lq.schedule(ev)
            scheduled += 1
        for _ in range(qrng.next_u64() % 3):
            if len(hq) == 0:
                break
            a, b = hq.pop(), lq.pop()
            popped += 1
            mismatch = mismatch or a != b
            last = a[0]
    while len(hq):
        a, b = hq.pop(), lq.pop()
        popped += 1
        mismatch = mismatch or a != b
    check(
        "randomized interleaved schedule/pop identical",
        not mismatch and popped == scheduled and len(lq) == 0,
        f"{popped}/{scheduled}",
    )

    print("event queue: ladder == heap through the full engine")

    def drop_wallclock(stats):
        # replan_cost_ns is measured wall time; every other stat is deterministic.
        return {k: v for k, v in stats.items() if k != "replan_cost_ns"}

    for name in ["open-poisson", "open-qos", "open-fault"]:
        for cell in scenario_cells(specs[name]):
            rh, sh, _ = scenario_rep(specs[name], cell, 0, equeue="heap")
            rl, sl, _ = scenario_rep(specs[name], cell, 0, equeue="ladder")
            check(
                f"{name} {cell['label']} rep0 identical under ladder",
                rh == rl and drop_wallclock(sh) == drop_wallclock(sl),
            )

    print("engine-capacity scenario (slab/ladder core pin)")
    sc_eng = specs["engine-capacity"]
    eng_res, eng_stats, _ = scenario_rep(
        sc_eng, scenario_cells(sc_eng)[0], 0, equeue="ladder"
    )
    check(
        "rep0 completes all 400 jobs, none rejected",
        len(eng_res) == 400 and not any(r["rejected"] for r in eng_res),
    )
    check(
        "engine tracks events / concurrency / memory",
        eng_stats["events"] > 400 * 4
        and eng_stats["max_inflight"] >= 1
        and eng_stats["mem_high_water"] > 0,
        f"ev={eng_stats['events']} conc={eng_stats['max_inflight']}",
    )

    print("ckms sketch: rank error within eps (stats.rs property tests)")
    srng = pm.Pcg32.seeded(11)
    xs_sk = [math.exp(srng.gen_f64() * 6.0) for _ in range(30000)]
    eps = 0.01
    sk = CkmsSketch(eps)
    for x in xs_sk:
        sk.insert(x)
    srt = sorted(xs_sk)
    n_sk = len(xs_sk)

    def rank_ok(sketch, values_sorted, p, tol):
        q = sketch.query(p)
        lo = bisect.bisect_left(values_sorted, q) + 1
        hi = bisect.bisect_right(values_sorted, q)
        target = math.ceil(p / 100.0 * len(values_sorted))
        slack = tol * len(values_sorted) + 1
        return lo - slack <= target <= hi + slack

    check(
        "sequential queries within eps*n ranks",
        all(rank_ok(sk, srt, p, eps) for p in [50.0, 90.0, 95.0, 99.0]),
    )
    check(
        "summary stays sublinear",
        len(sk.tuples) < n_sk // 10,
        f"{len(sk.tuples)} tuples for {n_sk} samples",
    )
    merged = CkmsSketch(eps)
    for chunk in (xs_sk[:9000], xs_sk[9000:21000], xs_sk[21000:]):
        part = CkmsSketch(eps)
        for x in chunk:
            part.insert(x)
        merged.merge(part)
    check(
        "merged sketch within 2*eps ranks",
        merged.n == n_sk
        and all(rank_ok(merged, srt, p, 2.0 * eps) for p in [50.0, 95.0, 99.0]),
    )
    check("empty sketch queries 0.0", CkmsSketch(eps).query(95.0) == 0.0)

    print("quantile acc: exact below the spill threshold, sketched above")
    acc = QuantileAcc()
    for v in xs_sk[:1000]:
        acc.push(v)
    check(
        "below threshold bit-identical to nearest rank",
        not acc.is_sketched()
        and all(
            acc.percentile(p) == percentile_nearest_rank(sorted(xs_sk[:1000]), p)
            for p in [50.0, 95.0, 99.0]
        ),
    )
    acc2 = QuantileAcc()
    for v in xs_sk[:17000]:
        acc2.push(v)
    srt17 = sorted(xs_sk[:17000])
    check(
        "spills past EXACT_SOJOURN_LIMIT and keeps the count",
        acc2.is_sketched() and acc2.count() == 17000,
    )
    check(
        "spilled answers within SKETCH_EPS ranks",
        all(rank_ok(acc2.sketch, srt17, p, SKETCH_EPS) for p in [50.0, 95.0, 99.0]),
    )

    print("streaming tally == vector session metrics below the spill")
    res_s, _, st_stats = open_run(open_dags, "dmda", open_submits, 8, model=model)
    vec_m = session_metrics(res_s, PAPER_WORKERS)
    str_m = streaming_session_metrics(res_s, PAPER_WORKERS, st_stats["max_inflight"])
    # mean_sojourn is summation-order sensitive: session_metrics sums
    # the sorted sojourn list, the streaming fold sums in results order
    # (as the Rust tally does), so those two agree only to the ulp.
    # Everything else — including the percentiles, which is the point of
    # the exact-below-threshold path — must match bit for bit.
    check(
        "streaming fold bit-identical",
        all(
            str_m[key] == vec_m[key]
            for key in [
                "span", "p50", "p95", "p99", "mean_qdelay",
                "throughput", "rejected", "deadline_hit_rate", "max_concurrent",
                "utilization",
            ]
        )
        and abs(str_m["mean_sojourn"] - vec_m["mean_sojourn"])
        <= 1e-12 * abs(vec_m["mean_sojourn"])
        and not str_m["sojourn_sketched"],
    )

    print("report path: heavily-rejecting session stays finite")
    rj_submits = bursty_times(2000.0, 16, 7, 32)
    rj, _, rj_stats = open_run(
        [chain(4, MM, 256)] * 32, "dmda", rj_submits, 1, model=model,
        admit="reject", stream_budget=0.01,
    )
    rj_m = streaming_session_metrics(rj, PAPER_WORKERS, rj_stats["max_inflight"])
    check(
        "rejected-heavy metrics all finite",
        rj_m["rejected"] > 0
        and all(
            math.isfinite(rj_m[key])
            for key in [
                "span", "p50", "p95", "p99", "mean_sojourn", "mean_qdelay",
                "throughput", "deadline_hit_rate",
            ]
        ),
        f"rejected={rj_m['rejected']}",
    )

    print("device utilization keeps the span denominator")
    busy_tot = sum(sum(r["device_busy"]) for r in res_s)
    recovered = sum(
        u * vec_m["span"] * w for u, w in zip(vec_m["utilization"], PAPER_WORKERS)
    )
    check(
        "sum util*span*workers recovers total busy time",
        abs(recovered - busy_tot) <= 1e-9 * max(busy_tot, 1.0),
        f"{recovered:.6f} vs {busy_tot:.6f}",
    )

    print("incremental replanning: warm-start cost vs from-scratch (tentpole margin)")
    inc_jobs = [phased(8, 4, 256) for _ in range(96)]
    inc_submits = poisson_times(220.0, 7, 96)
    inc_res, inc_pol, _ = open_run(inc_jobs, "gp:window=64", inc_submits, 8)
    scr_res, scr_pol, _ = open_run(
        inc_jobs, "gp:window=64,incremental=0", inc_submits, 8
    )
    inc_rs, scr_rs = inc_pol.rstats, scr_pol.rstats
    check("both arms execute replans", inc_rs["replans"] > 0 and scr_rs["replans"] > 0,
          f"inc={inc_rs['replans']} scratch={scr_rs['replans']}")
    inc_mean = inc_rs["cost_ns"] / max(inc_rs["replans"], 1)
    scr_mean = scr_rs["cost_ns"] / max(scr_rs["replans"], 1)
    print(
        f"    mean replan cost: incremental {inc_mean / 1e6:.3f} ms vs "
        f"scratch {scr_mean / 1e6:.3f} ms ({scr_mean / max(inc_mean, 1):.1f}x)"
    )
    check("incremental >=5x cheaper per replan", inc_mean * 5.0 <= scr_mean,
          f"{scr_mean / max(inc_mean, 1):.2f}x")
    inc_m = session_metrics(inc_res, PAPER_WORKERS)
    scr_m = session_metrics(scr_res, PAPER_WORKERS)
    print(
        f"    mean sojourn: incremental {inc_m['mean_sojourn']:.2f} ms vs "
        f"scratch {scr_m['mean_sojourn']:.2f} ms"
    )
    check("incremental mean sojourn no worse",
          inc_m["mean_sojourn"] <= scr_m["mean_sojourn"] * 1.001,
          f"{inc_m['mean_sojourn']:.2f} vs {scr_m['mean_sojourn']:.2f}")

    print("incremental replanning: warm cut within 2% of from-scratch (same graphs)")
    cut_model = CalibratedModel()
    cut_pol = make_open_policy("gp:window=64", len(PAPER_WORKERS), cut_model)
    cut_pol.record_cuts = []
    simulate_open_engine(
        list(zip(inc_jobs, inc_submits)), cut_pol, PAPER_WORKERS, cut_model, 8
    )
    warm_tot = sum(w for w, _ in cut_pol.record_cuts)
    scratch_tot = sum(s for _, s in cut_pol.record_cuts)
    print(
        f"    {len(cut_pol.record_cuts)} replans: warm cut sum {warm_tot} vs "
        f"scratch {scratch_tot} ({warm_tot / max(scratch_tot, 1):.4f}x)"
    )
    check("warm total cut within 2% of scratch",
          warm_tot <= scratch_tot * 1.02 + 1,
          f"{warm_tot} vs {scratch_tot}")

    print("incremental replanning: unchanged frontier epoch skips the replan")
    skip_pol = OpenGpWindow(len(PAPER_WORKERS), CalibratedModel(), window=4)
    skip_dag = phased(6, 2, 256)
    skip_pol.on_submit(0, skip_dag)
    for v in range(2):
        skip_pol.select(dict(job=0, task=v, deadline=math.inf,
                             device_free=[0.0] * len(PAPER_WORKERS)))
    for t in range(4):
        skip_pol.on_task_finish(0, t, 0, float(t))
    after_first = dict(skip_pol.rstats)
    check("window fires one real replan",
          after_first["replans"] == 1 and after_first["skipped"] == 0,
          f"{after_first}")
    for t in range(4, 8):
        skip_pol.on_task_finish(0, t, 0, float(t))
    check("no-change window skipped, cost not billed",
          skip_pol.rstats["replans"] == 1 and skip_pol.rstats["skipped"] == 1
          and skip_pol.rstats["cost_ns"] == after_first["cost_ns"],
          f"{skip_pol.rstats}")
    skip_pol.select(dict(job=0, task=2, deadline=math.inf,
                         device_free=[0.0] * len(PAPER_WORKERS)))
    for t in range(8, 12):
        skip_pol.on_task_finish(0, t, 0, float(t))
    check("dispatch bumps the epoch, next window replans",
          skip_pol.rstats["replans"] == 2 and skip_pol.rstats["skipped"] == 1,
          f"{skip_pol.rstats}")

    print("shared admission core (twin of sim::admission)")
    # Pop sequences pinned to the Rust unit tests bit-for-bit.
    core = AdmissionCore(1, "fifo")
    core.push_pending(2, 9, 1.0, 1.0)
    core.push_pending(5, 0, 0.0, 0.0)
    core.push_pending(3, 1, 0.5, 0.5)
    check("fifo pops in arrival order regardless of keys",
          [core.pop_pending() for _ in range(4)] == [2, 3, 5, None])
    core = AdmissionCore(1, "edf")
    core.push_pending(0, 1, 5.0, 0.0)
    core.push_pending(1, 0, 90.0, 0.0)
    core.push_pending(2, 0, 10.0, 0.0)
    check("edf orders by priority then deadline",
          [core.pop_pending() for _ in range(3)] == [2, 1, 0])
    core = AdmissionCore(1, "sjf")
    core.push_pending(0, 0, 0.0, 7.0)
    core.push_pending(1, 0, 0.0, 2.0)
    core.push_pending(2, 0, 0.0, 2.0)
    check("sjf orders by work with job tiebreak",
          [core.pop_pending() for _ in range(3)] == [1, 2, 0])
    core = AdmissionCore(1, "sjf")
    core.push_pending(0, 0, 0.0, float("nan"))
    core.push_pending(1, 0, 0.0, 3.0)
    core.push_pending(2, 0, 0.0, float("nan"))
    check("nan keys sort last (totalOrder), job id breaks the nan tie",
          [core.pop_pending() for _ in range(3)] == [1, 0, 2])
    core = AdmissionCore(2, "reject")
    core.note_admitted()
    core.note_admitted()
    core.push_pending(2, 0, math.inf, 30.0)
    check("predictive reject fires only on finite exceeded budgets",
          not core.predicts_reject(math.inf)
          and core.predicts_reject(25.0)
          and not core.predicts_reject(40.0)
          and core.remove_pending(2)
          and not core.remove_pending(2))

    # Bit-identity of the two admission drivers: the real executor's
    # event loop (arrivals drained before completions at each instant,
    # pops from the shared core) must reproduce the serial-window
    # closed form for FIFO — the ISSUE's queue=1 equivalence, plus the
    # general serial queue=w case.
    def core_window_admit(submits, services, queue):
        core = AdmissionCore(queue, "fifo")
        admit = [0.0] * len(submits)
        completes = [0.0] * len(submits)
        events = [(s, 0, i) for i, s in enumerate(submits)]
        heapq.heapify(events)
        prev_end = 0.0
        def start(i, now):
            nonlocal prev_end
            core.note_admitted()
            admit[i] = now
            end = max(now, prev_end) + services[i]
            prev_end = end
            completes[i] = end
            heapq.heappush(events, (end, 1, i))
        while events:
            now, kind, i = heapq.heappop(events)
            if kind == 0:
                if core.has_slot():
                    start(i, now)
                else:
                    core.push_pending(i, 0, math.inf, services[i])
            else:
                core.release_slot()
                nxt = core.pop_pending()
                if nxt is not None:
                    start(nxt, now)
        return admit, completes
    rng = pm.Pcg32.seeded(11)
    submits = []
    tacc = 0.0
    for _ in range(40):
        tacc += (rng.next_u32() % 1000) / 250.0
        submits.append(tacc)
    services = [1.0 + (rng.next_u32() % 1000) / 100.0 for _ in range(40)]
    for w in (1, 2, 5):
        admit, completes = core_window_admit(submits, services, w)
        ref = [serial_window_admit(submits[i], i, w, completes)
               for i in range(len(submits))]
        check(f"admission-core driver == serial_window_admit (queue={w})",
              admit == ref)

    print("ALL OK" if OK else "FAILURES PRESENT")
    return OK


# ----------------------------------------------------------------- golden

GOLDEN_CASES = [
    (MA, 1024, "eager"),
    (MA, 1024, "dmda"),
    (MA, 1024, "gp"),
    (MM, 1024, "eager"),
    (MM, 1024, "dmda"),
    (MM, 1024, "gp"),
]


def golden_rows():
    rows = []
    for kernel, size, name in GOLDEN_CASES:
        dag = generate_layered(paper_gen_cfg(kernel, size))
        r = run(dag, name)
        rows.append(
            dict(
                kernel=kernel,
                size=size,
                policy=name,
                assignments="".join(str(a) for a in r["assignments"]),
                transfers=r["ledger_count"],
                transfer_bytes=r["ledger_bytes"],
                makespan=r["makespan"],
            )
        )
    return rows


def print_golden():
    print("// generated by python/tools/sched_mirror.py golden")
    for row in golden_rows():
        print(
            f'    ("{row["kernel"]}", {row["size"]}, "{row["policy"]}", '
            f'"{row["assignments"]}", {row["transfers"]}, {row["transfer_bytes"]}, '
            f'{row["makespan"]!r}),'
        )


# ------------------------------------------------------------------ bench

DEFAULT_OPEN_STREAM = "stream:arrival=poisson,rate=220,queue=8"


def job_mix(jobs, size, seed):
    """Mirror of workloads::job_mix."""
    out = []
    for i in range(jobs):
        if i % 2 == 0:
            out.append(phased(8, 4, size))
        else:
            out.append(generate_layered(scaled_gen_cfg(24, MA, size, seed + i)))
    return out


def structural_hit_rate(dags):
    """Plan-cache hit pattern by structure (mirror of PlanKey's dag
    fingerprint role): hits = jobs whose signature was seen before."""
    seen = set()
    hits = 0
    for dag in dags:
        sig = dag_signature(dag)
        if sig in seen:
            hits += 1
        else:
            seen.add(sig)
    return hits / len(dags) if dags else 0.0


DEFAULT_QOS_STREAM = "stream:arrival=bursty,rate=380,burst=8,queue=2,seed=7"


def bench_json(jobs=8, window=12, size=1024, open_jobs=24, rate=220.0, queue=8):
    import time

    model = CalibratedModel()
    workers = PAPER_WORKERS
    open_submits = poisson_times(rate, 7, open_jobs)
    stream_spec = f"stream:arrival=poisson,rate={rate:g},queue={queue},seed=7"
    scenarios = [
        ("repeat-mm", [generate_layered(paper_gen_cfg(MM, size)) for _ in range(jobs)], None),
        ("repeat-ma", [generate_layered(paper_gen_cfg(MA, size)) for _ in range(jobs)], None),
        ("phased", [phased(8, 4, 256) for _ in range(min(jobs, 4))], None),
        ("open-poisson", [phased(8, 4, 256) for _ in range(open_jobs)], open_submits),
        ("open-mix", job_mix(open_jobs, 256, 2015), open_submits),
    ]
    rows = []

    def push_row(scenario, spec, stream, dags, results, plan_ns, first_plan_ns,
                 n_classes=1, names=(), stats=None):
        m = session_metrics(results, workers)
        st = stats or dict(failures=0, reexec=0, wasted=0.0, executed=0.0, replans=0)
        # Mirror of SessionReport::goodput_jps: throughput weighted by
        # the useful share of all executed work.
        useful = sum(sum(r["device_busy"]) for r in results)
        total = useful + st["wasted"]
        goodput = m["throughput"] if total <= 0.0 else m["throughput"] * useful / total
        rows.append(
            dict(
                scenario=scenario,
                policy=spec,
                stream=stream,
                jobs=len(dags),
                makespan_ms=sum(r["makespan"] for r in results),
                span_ms=m["span"],
                transfers=sum(r["ledger_count"] for r in results),
                plan_ns=plan_ns,
                first_plan_ns=first_plan_ns,
                repeat_plan_ns=0,
                cache_hit_rate=structural_hit_rate(dags),
                decision_ns=0,
                p50_sojourn_ms=m["p50"],
                p95_sojourn_ms=m["p95"],
                p99_sojourn_ms=m["p99"],
                mean_sojourn_ms=m["mean_sojourn"],
                mean_queue_delay_ms=m["mean_qdelay"],
                throughput_jps=m["throughput"],
                max_concurrent_jobs=m["max_concurrent"],
                rejected=m["rejected"],
                deadline_hit_rate=m["deadline_hit_rate"],
                failures_injected=st["failures"],
                tasks_reexecuted=st["reexec"],
                wasted_work_ms=st["wasted"],
                useful_work_ms=useful,
                executed_work_ms=st["executed"],
                recovery_replans=st["replans"],
                goodput_jps=goodput,
                replans=st.get("session_replans", 0),
                replan_cost_ms=st.get("replan_cost_ns", 0) / 1e6,
                utilization=m["utilization"],
                classes=class_metrics(results, m["span"], n_classes, list(names)),
            )
        )

    for scenario, dags, submits in scenarios:
        specs = ["eager", "dmda", "heft", "gp", f"gp:window={window}"]
        if scenario == "open-poisson":
            # Incremental-replanning headline rows: warm-start default
            # vs the from-scratch baseline arm on the same stream.
            specs += ["gp:window=64", "gp:window=64,incremental=0"]
        for spec in specs:
            plan_ns = 0
            first_plan_ns = 0
            row_stats = None
            if submits is None:
                # Closed loop: back-to-back fresh-machine runs; the
                # recovery counters accumulate across the per-job
                # engines (all zero but executed, which equals useful).
                results = []
                clock = 0.0
                executed = 0.0
                session_replans = 0
                replan_cost_ns = 0
                for i, dag in enumerate(dags):
                    t0 = time.perf_counter_ns()
                    if spec.startswith("gp:window"):
                        r = run(dag, "gp-window", window=window)
                    elif spec == "heft":
                        r = run(dag, "dmda")
                    else:
                        r = run(dag, spec)
                    t1 = time.perf_counter_ns()
                    if i == 0 and spec.startswith("gp"):
                        first_plan_ns = t1 - t0
                        plan_ns += t1 - t0
                    rs = getattr(r["policy"], "rstats", None)
                    if rs:
                        session_replans += rs["replans"]
                        replan_cost_ns += rs["cost_ns"]
                    executed += r["executed_ms"]
                    results.append(
                        dict(
                            makespan=r["makespan"],
                            submit=clock,
                            admit=clock,
                            complete=clock + r["makespan"],
                            ledger_count=r["ledger_count"],
                            device_busy=r["device_busy"],
                        )
                    )
                    clock += r["makespan"]
                row_stats = dict(failures=0, reexec=0, wasted=0.0, executed=executed, replans=0,
                                 session_replans=session_replans, replan_cost_ns=replan_cost_ns)
                stream = "stream:arrival=closed"
            else:
                t0 = time.perf_counter_ns()
                results, _policy, row_stats = open_run(dags, spec, submits, queue, model=model)
                t1 = time.perf_counter_ns()
                if spec.startswith("gp"):
                    first_plan_ns = t1 - t0
                    plan_ns += t1 - t0
                stream = stream_spec
            push_row(scenario, spec, stream, dags, results, plan_ns, first_plan_ns,
                     stats=row_stats)

    # open-qos: classed traffic, admission-policy sweep under one
    # scheduler (mirror of cmd_bench_stream's sweep).
    mix = default_qos_mix()
    classed = job_classes(mix, open_jobs, 2015)
    qdags = [j["dag"] for j in classed]
    qqos = [j["qos"] for j in classed]
    qsubmits = bursty_times(380.0, 8, 7, open_jobs)
    for adm in ["fifo", "edf", "sjf", "reject"]:
        results, _, qstats = open_run(qdags, "dmda", qsubmits, 2, model=model, qos=qqos, admit=adm)
        stream = DEFAULT_QOS_STREAM if adm == "fifo" else f"{DEFAULT_QOS_STREAM},admit={adm}"
        push_row(
            "open-qos", "dmda", stream, qdags, results, 0, 0,
            n_classes=len(mix), names=[c["name"] for c in mix], stats=qstats,
        )

    # open-fault: the scripted mid-burst GPU kill under each recovery
    # strategy (mirror of cmd_bench_stream's open-fault sweep; the
    # stream column carries the arrival spec, the fault spec is fixed).
    fault_stream = stream_spec
    fault_dags = [phased(8, 4, 256) for _ in range(open_jobs)]
    for spec in ["dmda", "gp", f"gp:window={window}"]:
        plan_ns = 0
        first_plan_ns = 0
        t0 = time.perf_counter_ns()
        results, _policy, fstats = open_run(
            fault_dags, spec, open_submits, queue, model=model, fault=DEFAULT_FAULT
        )
        t1 = time.perf_counter_ns()
        if spec.startswith("gp"):
            first_plan_ns = t1 - t0
            plan_ns += t1 - t0
        push_row("open-fault", spec, fault_stream, fault_dags, results,
                 plan_ns, first_plan_ns, stats=fstats)
    lines = [
        "{",
        '  "bench": "sched_session",',
        '  "harness": "python-mirror",',
        f'  "requested_jobs": {jobs},',
        f'  "window": {window},',
        f'  "size": {size},',
        '  "rows": [',
    ]
    def esc(s):
        # Mirror of main.rs json_escape: backslash, quote, control chars.
        out = []
        for ch in s:
            if ch == "\\":
                out.append("\\\\")
            elif ch == '"':
                out.append('\\"')
            elif ord(ch) < 0x20:
                out.append(f"\\u{ord(ch):04x}")
            else:
                out.append(ch)
        return "".join(out)

    for i, r in enumerate(rows):
        comma = "" if i + 1 == len(rows) else ","
        util = ", ".join(f"{u:.4f}" for u in r["utilization"])
        classes = ", ".join(
            f'{{"name": "{esc(c["name"])}", "jobs": {c["jobs"]}, "rejected": {c["rejected"]}, '
            f'"p50_sojourn_ms": {c["p50"]:.6f}, "p95_sojourn_ms": {c["p95"]:.6f}, '
            f'"p99_sojourn_ms": {c["p99"]:.6f}, "mean_sojourn_ms": {c["mean_sojourn"]:.6f}, '
            f'"deadline_hit_rate": {c["deadline_hit_rate"]:.4f}, '
            f'"throughput_jps": {c["throughput"]:.6f}}}'
            for c in r["classes"]
        )
        lines.append(
            f'    {{"scenario": "{r["scenario"]}", "policy": "{r["policy"]}", '
            # The mirror can only produce simulated rows: real-engine
            # rows are wall-clock measurements the Rust CLI appends
            # under `bench stream --real`.
            f'"stream": "{r["stream"]}", "engine": "sim", "jobs": {r["jobs"]}, '
            f'"makespan_ms": {r["makespan_ms"]:.6f}, "span_ms": {r["span_ms"]:.6f}, '
            f'"transfers": {r["transfers"]}, "plan_ns": {r["plan_ns"]}, '
            f'"first_plan_ns": {r["first_plan_ns"]}, "repeat_plan_ns": {r["repeat_plan_ns"]}, '
            f'"cache_hit_rate": {r["cache_hit_rate"]:.4f}, "decision_ns": {r["decision_ns"]}, '
            f'"p50_sojourn_ms": {r["p50_sojourn_ms"]:.6f}, '
            f'"p95_sojourn_ms": {r["p95_sojourn_ms"]:.6f}, '
            f'"p99_sojourn_ms": {r["p99_sojourn_ms"]:.6f}, '
            f'"mean_sojourn_ms": {r["mean_sojourn_ms"]:.6f}, '
            f'"mean_queue_delay_ms": {r["mean_queue_delay_ms"]:.6f}, '
            f'"throughput_jps": {r["throughput_jps"]:.6f}, '
            f'"max_concurrent_jobs": {r["max_concurrent_jobs"]}, '
            f'"rejected": {r["rejected"]}, '
            f'"deadline_hit_rate": {r["deadline_hit_rate"]:.4f}, '
            f'"failures_injected": {r["failures_injected"]}, '
            f'"tasks_reexecuted": {r["tasks_reexecuted"]}, '
            f'"wasted_work_ms": {r["wasted_work_ms"]:.6f}, '
            f'"useful_work_ms": {r["useful_work_ms"]:.6f}, '
            f'"executed_work_ms": {r["executed_work_ms"]:.6f}, '
            f'"recovery_replans": {r["recovery_replans"]}, '
            f'"goodput_jps": {r["goodput_jps"]:.6f}, '
            f'"replans": {r["replans"]}, '
            f'"replan_cost_ms": {r["replan_cost_ms"]:.6f}, '
            f'"utilization": [{util}], "classes": [{classes}]}}{comma}'
        )
    lines.append("  ]")
    lines.append("}")
    return "\n".join(lines) + "\n"


def tune():
    model = CalibratedModel()
    for width, depth, size in [(8, 4, 1024), (8, 4, 512), (12, 3, 1024), (6, 6, 1024)]:
        dag = phased(width, depth, size)
        one = run(dag, "gp")
        e = run(dag, "eager")
        d = run(dag, "dmda")
        line = f"phased({width},{depth},{size}): eager {e['makespan']:.2f} dmda {d['makespan']:.2f} gp {one['makespan']:.2f}"
        for window in [4, 8, 12, 16, 24]:
            win = run(dag, "gp-window", window=window)
            line += f" | w{window} {win['makespan']:.2f}"
        print(line)


if __name__ == "__main__":
    cmd = sys.argv[1] if len(sys.argv) > 1 else "checks"
    if cmd == "checks":
        sys.exit(0 if run_checks() else 1)
    elif cmd == "golden":
        print_golden()
    elif cmd == "bench":
        out = bench_json()
        path = os.path.join(
            os.path.dirname(os.path.abspath(__file__)),
            "..", "..", "rust", "bench_results", "BENCH_sched_session.json",
        )
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(out)
        print(f"written {os.path.normpath(path)}")
    elif cmd == "scenarios":
        out = bench_scenarios_json()
        path = os.path.join(
            os.path.dirname(os.path.abspath(__file__)),
            "..", "..", "rust", "bench_results", "BENCH_scenarios.json",
        )
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(out)
        print(f"written {os.path.normpath(path)}")
    elif cmd == "engine":
        out = bench_engine_json()
        path = os.path.join(
            os.path.dirname(os.path.abspath(__file__)),
            "..", "..", "rust", "bench_results", "BENCH_engine.json",
        )
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(out)
        print(f"written {os.path.normpath(path)}")
    elif cmd == "tune":
        tune()
    else:
        raise SystemExit(f"unknown command {cmd!r}")
