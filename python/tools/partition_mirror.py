"""Python mirror of the Rust CSR multilevel partitioner.

A line-for-line transliteration of ``rust/src/partition`` (CSR substrate,
bucket-gain FM, zero-copy recursive bisection) plus the in-tree PCG32,
used to validate algorithm logic and partition quality in environments
without a Rust toolchain. The mirror follows the Rust code's control
flow exactly — including PCG32 bit-exactness and Rust's
``Iterator::max_by_key`` last-max tie-breaking — so corpus outcomes here
predict the Rust implementation's outcomes.

Run:  python3 python/tools/partition_mirror.py          # corpus checks
      python3 python/tools/partition_mirror.py bench    # quality vs seed algo
"""

import sys
import time
import heapq

M32 = 0xFFFFFFFF
M64 = 0xFFFFFFFFFFFFFFFF
PCG_MULT = 6364136223846793005


class Pcg32:
    """Bit-exact mirror of rust/src/util/rng.rs."""

    def __init__(self, seed, stream=54):
        self.state = 0
        self.inc = ((stream << 1) | 1) & M64
        self.next_u32()
        self.state = (self.state + seed) & M64
        self.next_u32()

    @staticmethod
    def seeded(seed):
        return Pcg32(seed, 54)

    def next_u32(self):
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & M64
        xorshifted = (((old >> 18) ^ old) >> 27) & M32
        rot = (old >> 59) & 31
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & M32

    def next_u64(self):
        hi = self.next_u32()
        return ((hi << 32) | self.next_u32()) & M64

    def gen_range(self, bound):
        assert bound > 0
        threshold = ((M32 + 1) - bound) % bound
        while True:
            r = self.next_u32()
            if r >= threshold:
                return r % bound

    def gen_range_usize(self, lo, hi):
        assert lo < hi
        return lo + self.gen_range(hi - lo)

    def gen_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def gen_bool(self, p):
        return self.gen_f64() < p

    def shuffle(self, lst):
        for i in range(len(lst) - 1, 0, -1):
            j = self.gen_range(i + 1)
            lst[i], lst[j] = lst[j], lst[i]

    def choose(self, lst):
        assert lst
        return lst[self.gen_range(len(lst))]


def last_max_by_key(iterable, key):
    """Rust Iterator::max_by_key: last element among equal maxima."""
    best = None
    best_k = None
    for x in iterable:
        k = key(x)
        if best_k is None or k >= best_k:
            best, best_k = x, k
    return best


class MetisGraph:
    """CSR graph: vwgt, xadj, adjncy, adjwgt."""

    def __init__(self, vwgt, xadj, adjncy, adjwgt):
        self.vwgt = vwgt
        self.xadj = xadj
        self.adjncy = adjncy
        self.adjwgt = adjwgt

    @staticmethod
    def from_adj(vwgt, adj):
        xadj = [0]
        adjncy = []
        adjwgt = []
        for row in adj:
            for (u, w) in row:
                adjncy.append(u)
                adjwgt.append(w)
            xadj.append(len(adjncy))
        return MetisGraph(vwgt, xadj, adjncy, adjwgt)

    def vertex_count(self):
        return len(self.vwgt)

    def edge_count(self):
        return len(self.adjncy) // 2

    def neighbors(self, v):
        for i in range(self.xadj[v], self.xadj[v + 1]):
            yield self.adjncy[i], self.adjwgt[i]

    def vertex_weight(self, v):
        return self.vwgt[v]

    def total_vertex_weight(self):
        return sum(self.vwgt)


class SubsetView:
    def __init__(self, g, verts, local):
        self.g = g
        self.verts = verts
        self.local = local

    def vertex_count(self):
        return len(self.verts)

    def vertex_weight(self, v):
        return self.g.vwgt[self.verts[v]]

    def neighbors(self, v):
        for (u, w) in self.g.neighbors(self.verts[v]):
            lu = self.local[u]
            if lu is not None:
                yield lu, w

    def total_vertex_weight(self):
        return sum(self.g.vwgt[v] for v in self.verts)


def csr_build(vwgt, edges):
    """Mirror of CsrBuilder::build (counting scatter + sort + merge)."""
    n = len(vwgt)
    xadj = [0] * (n + 1)
    for (u, v, _) in edges:
        xadj[u + 1] += 1
        xadj[v + 1] += 1
    for v in range(n):
        xadj[v + 1] += xadj[v]
    m2 = xadj[n]
    adjncy = [0] * m2
    adjwgt = [0] * m2
    cursor = list(xadj)
    for (u, v, w) in edges:
        adjncy[cursor[u]] = v
        adjwgt[cursor[u]] = w
        cursor[u] += 1
        adjncy[cursor[v]] = u
        adjwgt[cursor[v]] = w
        cursor[v] += 1
    out_xadj = [0] * (n + 1)
    out_n = []
    out_w = []
    for v in range(n):
        row = sorted(
            zip(adjncy[xadj[v]:xadj[v + 1]], adjwgt[xadj[v]:xadj[v + 1]]),
            key=lambda p: p[0],
        )
        out_xadj[v] = len(out_n)
        i = 0
        while i < len(row):
            u, w = row[i]
            i += 1
            while i < len(row) and row[i][0] == u:
                w += row[i][1]
                i += 1
            out_n.append(u)
            out_w.append(w)
    out_xadj[n] = len(out_n)
    return MetisGraph(vwgt, out_xadj, out_n, out_w)


# ---------------------------------------------------------------- quality

def edge_cut(g, parts):
    cut = 0
    for v in range(g.vertex_count()):
        pv = parts[v]
        for (u, w) in g.neighbors(v):
            if parts[u] != pv:
                cut += w
    return cut // 2


def part_weights(g, parts, k):
    w = [0] * k
    for v in range(g.vertex_count()):
        w[parts[v]] += g.vertex_weight(v)
    return w


# ---------------------------------------------------------------- coarsen

class CoarseLevel:
    def __init__(self):
        self.map = []
        self.coarse = None
        self.coarse_fixed = []

    def project(self, coarse_side):
        return [coarse_side[c] for c in self.map]


def coarsen_once(fine, fixed, rng):
    n = fine.vertex_count()
    order = list(range(n))
    rng.shuffle(order)
    matched = [None] * n
    for v in order:
        if matched[v] is not None:
            continue
        best_u = None
        best_w = None
        for (u, w) in fine.neighbors(v):
            compatible = fixed[v] < 0 or fixed[u] < 0 or fixed[v] == fixed[u]
            if u != v and matched[u] is None and compatible and (
                best_w is None or w > best_w
            ):
                best_u, best_w = u, w
        if best_u is not None:
            matched[v] = best_u
            matched[best_u] = v
        else:
            matched[v] = v

    out = CoarseLevel()
    cmap = [None] * n
    nxt = 0
    for v in range(n):
        if cmap[v] is not None:
            continue
        cmap[v] = nxt
        m = matched[v]
        if m != v:
            cmap[m] = nxt
        nxt += 1
    out.map = cmap
    nc = nxt

    vwgt = [0] * nc
    for v in range(n):
        vwgt[cmap[v]] += fine.vertex_weight(v)
    cf = [-1] * nc
    for v in range(n):
        if fixed[v] >= 0:
            cf[cmap[v]] = fixed[v]
    out.coarse_fixed = cf

    counts = [0] * (nc + 1)
    for v in range(n):
        counts[cmap[v] + 1] += 1
    for c in range(nc):
        counts[c + 1] += counts[c]
    ordered = [0] * n
    cursor = list(counts)
    for v in range(n):
        c = cmap[v]
        ordered[cursor[c]] = v
        cursor[c] += 1

    xadj = [0]
    adjncy = []
    adjwgt = []
    acc = [0] * nc
    touched = []
    for c in range(nc):
        for idx in range(counts[c], counts[c + 1]):
            v = ordered[idx]
            for (u, w) in fine.neighbors(v):
                cu = cmap[u]
                if cu == c:
                    continue
                if acc[cu] == 0:
                    touched.append(cu)
                acc[cu] += w
        touched.sort()
        for cu in touched:
            adjncy.append(cu)
            adjwgt.append(acc[cu])
            acc[cu] = 0
        touched.clear()
        xadj.append(len(adjncy))
    out.coarse = MetisGraph(vwgt, xadj, adjncy, adjwgt)
    return out


# ---------------------------------------------------------------- initial

def greedy_growing(g, frac0, fixed, cfg, rng):
    n = g.vertex_count()
    total = g.total_vertex_weight()
    target0 = int(round(frac0 * total))

    best = None
    for _ in range(max(cfg["initial_tries"], 1)):
        side = grow_once(g, target0, fixed, rng)
        cut = edge_cut(g, side)
        if best is None or cut < best[0]:
            best = (cut, side)
    if best is None:
        return [0 if fixed[v] == 0 else 1 for v in range(n)]
    return best[1]


def grow_once(g, target0, fixed, rng):
    n = g.vertex_count()
    side = [0 if fixed[v] == 0 else 1 for v in range(n)]
    if n == 0:
        return side
    w0 = 0
    in0 = [False] * n
    pending = [v for v in range(n) if fixed[v] == 0]
    for v in pending:
        in0[v] = True
        w0 += g.vertex_weight(v)
    if w0 >= target0 and pending:
        return side
    gain = [0] * n
    in_frontier = [False] * n
    frontier = []

    def eligible(u):
        return fixed[u] < 0

    if not pending:
        free = [v for v in range(n) if eligible(v)]
        if not free or target0 <= 0:
            return side
        pending.append(rng.choose(free))

    nxt = pending[0]
    seeded = pending
    seed_idx = 1

    while nxt is not None:
        v = nxt
        if not in0[v]:
            in0[v] = True
            side[v] = 0
            w0 += g.vertex_weight(v)
        if w0 >= target0 and target0 > 0:
            break
        for (u, w) in g.neighbors(v):
            if in0[u] or not eligible(u):
                continue
            if not in_frontier[u]:
                in_frontier[u] = True
                init = 0
                for (x, xw) in g.neighbors(u):
                    init += xw if in0[x] else -xw
                gain[u] = init
                frontier.append(u)
            else:
                gain[u] += 2 * w
        if seed_idx < len(seeded):
            seed_idx += 1
            nxt = seeded[seed_idx - 1]
        else:
            frontier[:] = [u for u in frontier if not in0[u]]
            if frontier:
                nxt = last_max_by_key(frontier, lambda u: gain[u])
            else:
                cand = [u for u in range(n) if not in0[u] and eligible(u)]
                nxt = last_max_by_key(cand, lambda _u: rng.next_u32()) if cand else None
        if nxt is None:
            break
    return side


# ----------------------------------------------------------------- refine

# Mirror of refine.rs leaf layout: exact gain classes (+-EXACT_GAIN)
# subdivided by vertex-id chunk, log2 tails beyond.
EXACT_GAIN = 128
NCHUNK = 256
NTAIL = 57
EXACT_BASE = NTAIL
POS_TAIL_BASE = EXACT_BASE + (2 * EXACT_GAIN + 1) * NCHUNK
NLEAF = POS_TAIL_BASE + NTAIL


class GainBuckets:
    """Leaf-keyed bucket queue: (gain class, v chunk), LIFO per leaf.

    The Rust version indexes nonempty leaves with a 3-level bitmap; here a
    dict of lists plus a `highest` scan pointer keeps identical pop order
    (highest leaf, LIFO within), which is all that matters for parity.
    """

    def __init__(self):
        self.lists = {}
        self.leaf = []
        self.shift = 0
        self.highest = 0
        self.gain_shift = 0

    def reset(self, n):
        self.lists = {}
        self.leaf = [None] * n
        self.shift = 0
        while n > (NCHUNK << self.shift):
            self.shift += 1
        self.highest = 0
        self.gain_shift = 0

    def set_gain_shift(self, shift):
        self.gain_shift = shift

    def leaf_of(self, v, gain):
        gain = gain >> self.gain_shift
        if -EXACT_GAIN <= gain <= EXACT_GAIN:
            return EXACT_BASE + (gain + EXACT_GAIN) * NCHUNK + (v >> self.shift)
        if gain > 0:
            return POS_TAIL_BASE + (gain.bit_length() - 1 - 7)
        return (NTAIL - 1) - ((-gain).bit_length() - 1 - 7)

    def contains(self, v):
        return self.leaf[v] is not None

    def insert(self, v, gain):
        l = self.leaf_of(v, gain)
        self.leaf[v] = l
        self.lists.setdefault(l, []).append(v)
        if l > self.highest:
            self.highest = l

    def remove(self, v):
        l = self.leaf[v]
        if l is None:
            return
        self.lists[l].remove(v)
        self.leaf[v] = None

    def reposition(self, v, gain):
        l = self.leaf_of(v, gain)
        if self.leaf[v] == l:
            return
        self.remove(v)
        self.insert(v, gain)

    def pop_best(self):
        while True:
            lst = self.lists.get(self.highest)
            if lst:
                v = lst.pop()
                self.leaf[v] = None
                return v
            if self.highest == 0:
                return None
            self.highest -= 1


def fm_refine(g, side, frac0, fixed, cfg, rng):
    n = g.vertex_count()
    if n == 0:
        return 0
    total = g.total_vertex_weight()
    target0 = frac0 * total
    target1 = total - target0
    max_vw = max((g.vertex_weight(v) for v in range(n)), default=0)
    import math
    lo0 = math.floor(target0 - (cfg["epsilon"] * target0 + max_vw))
    hi0 = math.ceil(target0 + (cfg["epsilon"] * target1 + max_vw))

    cut = edge_cut(g, side)
    for _ in range(max(cfg["refine_passes"], 1)):
        improved, cut = fm_pass(g, side, lo0, hi0, fixed, cut)
        if not improved:
            break
    return cut


def fm_pass(g, side, lo0, hi0, fixed, cut):
    n = g.vertex_count()
    gain = [0] * n
    locked = [False] * n
    log = []
    buckets = GainBuckets()
    buckets.reset(n)

    w0 = 0
    min_w = None
    seeds = []
    for v in range(n):
        sv = side[v]
        if sv == 0:
            w0 += g.vertex_weight(v)
        gsum = 0
        deg = 0
        boundary = False
        for (u, w) in g.neighbors(v):
            deg += 1
            if w > 0 and (min_w is None or w < min_w):
                min_w = w
            if side[u] != sv:
                gsum += w
                boundary = True
            else:
                gsum -= w
        gain[v] = gsum
        locked[v] = fixed[v] >= 0
        if not locked[v] and (boundary or deg == 0):
            seeds.append(v)
    gain_shift = 0 if min_w is None else min_w.bit_length() - 1
    buckets.set_gain_shift(gain_shift)
    for v in seeds:
        buckets.insert(v, gain[v])

    running_cut = cut
    best_cut = cut
    best_len = 0
    w0_start = w0
    best_key = None

    def dist(w):
        if w < lo0:
            return lo0 - w
        if w > hi0:
            return w - hi0
        return 0

    abort_after = max(50, n // 100)

    while True:
        v = buckets.pop_best()
        if v is None:
            break
        if len(log) >= best_len + abort_after:
            break
        gv = gain[v]
        new_w0 = w0 - g.vertex_weight(v) if side[v] == 0 else w0 + g.vertex_weight(v)
        if dist(new_w0) > 0 and dist(new_w0) >= dist(w0):
            continue
        if best_key is None:
            best_key = (dist(w0_start), cut)
        locked[v] = True
        sv_new = 1 - side[v]
        side[v] = sv_new
        w0 = new_w0
        running_cut -= gv
        log.append(v)
        key = (dist(w0), running_cut)
        if key < best_key:
            best_key = key
            best_cut = running_cut
            best_len = len(log)
        for (u, w) in g.neighbors(v):
            if locked[u]:
                continue
            delta = -2 * w if side[u] == sv_new else 2 * w
            gain[u] += delta
            if buckets.contains(u):
                buckets.reposition(u, gain[u])
            else:
                buckets.insert(u, gain[u])

    for v in reversed(log[best_len:]):
        side[v] = 1 - side[v]
    improved = best_len > 0
    return improved, (best_cut if improved else cut)


# ------------------------------------------- k-way direct refinement

def kway_refine(g, parts, targets, fixed, cfg):
    """Mirror of refine::kway_refine_ws."""
    import math
    n = g.vertex_count()
    k = len(targets)
    cut = edge_cut(g, parts)
    if n == 0 or k <= 1:
        return cut
    total = g.total_vertex_weight()
    max_vw = max((g.vertex_weight(v) for v in range(n)), default=0)
    lo = []
    hi = []
    for p in range(k):
        tp = targets[p] * total
        lo.append(math.floor(tp - (cfg["epsilon"] * tp + max_vw)))
        hi.append(math.ceil(tp + (cfg["epsilon"] * tp + max_vw)))
    for _ in range(max(cfg["refine_passes"], 1)):
        improved, cut = kway_pass(g, parts, k, fixed, lo, hi, cut)
        if not improved:
            break
    return cut


def kway_conn(g, parts, v, conn):
    """Mirror of refine::kway_conn: conn[p] = edge weight from v into p."""
    for p in range(len(conn)):
        conn[p] = 0
    for (u, w) in g.neighbors(v):
        if w > 0:
            conn[parts[u]] += w


def kway_key(conn, a):
    """Mirror of refine::kway_key: best external gain."""
    best = None
    for p, c in enumerate(conn):
        if p != a and (best is None or c > best):
            best = c
    return best - conn[a]


def kway_best(conn, pwgts, lo, hi, a, w):
    """Mirror of refine::kway_best: min (dist_delta, -gain, p) over p != a."""

    def dist(p, x):
        return max(lo[p] - x, 0) + max(x - hi[p], 0)

    da = dist(a, pwgts[a] - w) - dist(a, pwgts[a])
    ca = conn[a]
    best = None
    for p in range(len(conn)):
        if p == a:
            continue
        gain = conn[p] - ca
        dd = da + dist(p, pwgts[p] + w) - dist(p, pwgts[p])
        cand = (dd, -gain, p)
        if best is None or cand < best:
            best = cand
    return best[2], -best[1], best[0]


def kway_pass(g, parts, k, fixed, lo, hi, cut):
    """Mirror of refine::kway_pass: greedy, no rollback; a move commits
    only when it strictly decreases (total band distance, cut)."""
    n = g.vertex_count()
    conn = [0] * k
    pwgts = [0] * k
    locked = [False] * n
    seeds = []
    buckets = GainBuckets()
    buckets.reset(n)
    for v in range(n):
        pwgts[parts[v]] += g.vertex_weight(v)
    any_oob = any(pwgts[p] < lo[p] or pwgts[p] > hi[p] for p in range(k))
    min_w = None
    for v in range(n):
        locked[v] = fixed[v] >= 0
        pv = parts[v]
        deg = 0
        boundary = False
        for (u, w) in g.neighbors(v):
            deg += 1
            if w > 0 and (min_w is None or w < min_w):
                min_w = w
            if parts[u] != pv:
                boundary = True
        if not locked[v] and (boundary or deg == 0 or any_oob):
            seeds.append(v)
    gain_shift = 0 if min_w is None else min_w.bit_length() - 1
    buckets.set_gain_shift(gain_shift)
    for v in seeds:
        kway_conn(g, parts, v, conn)
        buckets.insert(v, kway_key(conn, parts[v]))

    improved = False
    running_cut = cut
    while True:
        v = buckets.pop_best()
        if v is None:
            break
        a = parts[v]
        w = g.vertex_weight(v)
        kway_conn(g, parts, v, conn)
        p, gain, dd = kway_best(conn, pwgts, lo, hi, a, w)
        if not (dd < 0 or (dd == 0 and gain > 0)):
            continue
        parts[v] = p
        pwgts[a] -= w
        pwgts[p] += w
        running_cut -= gain
        locked[v] = True
        improved = True
        for (u, wu) in g.neighbors(v):
            if wu <= 0 or locked[u]:
                continue
            kway_conn(g, parts, u, conn)
            key = kway_key(conn, parts[u])
            if buckets.contains(u):
                buckets.reposition(u, key)
            else:
                buckets.insert(u, key)
    return improved, (running_cut if improved else cut)


# -------------------------------------------------------------- partition

def default_cfg(**kw):
    cfg = dict(
        k=2,
        targets=None,
        epsilon=0.05,
        seed=1,
        coarsen_until=64,
        initial_tries=8,
        refine_passes=4,
        fixed=None,
    )
    cfg.update(kw)
    return cfg


def bisect(g, frac0, fixed, cfg, rng):
    n = g.vertex_count()
    if n == 0:
        return []
    total = g.total_vertex_weight()
    target0 = frac0 * total
    pos = [g.vertex_weight(v) for v in range(n) if g.vertex_weight(v) > 0]
    min_w = min(pos) if pos else 1
    if target0 < min_w / 2.0:
        return [0 if fixed[v] == 0 else 1 for v in range(n)]
    if (total - target0) < min_w / 2.0:
        return [1 if fixed[v] == 1 else 0 for v in range(n)]

    levels = []
    while True:
        cur_n = levels[-1].coarse.vertex_count() if levels else n
        if cur_n <= cfg["coarsen_until"]:
            break
        if levels:
            lvl = coarsen_once(levels[-1].coarse, levels[-1].coarse_fixed, rng)
        else:
            lvl = coarsen_once(g, fixed, rng)
        if lvl.coarse.vertex_count() > 0.95 * cur_n:
            break
        levels.append(lvl)

    if levels:
        fg, ff = levels[-1].coarse, levels[-1].coarse_fixed
    else:
        fg, ff = g, fixed
    side = greedy_growing(fg, frac0, ff, cfg, rng)
    fm_refine(fg, side, frac0, ff, cfg, rng)

    for i in range(len(levels) - 1, -1, -1):
        side = levels[i].project(side)
        if i == 0:
            fm_refine(g, side, frac0, fixed, cfg, rng)
        else:
            fm_refine(
                levels[i - 1].coarse, side, frac0, levels[i - 1].coarse_fixed, cfg, rng
            )
    return side


def recursive_bisect(g, vs, targets, part_base, fixed, cfg, rng, parts, remap):
    k = len(targets)
    if k == 1:
        for v in vs:
            parts[v] = part_base
        return
    k_left = k // 2
    t_left = sum(targets[:k_left])
    t_right = sum(targets[k_left:])
    frac_left = t_left / (t_left + t_right)

    def side_pin(v):
        if fixed[v] < 0:
            return -1
        return 0 if fixed[v] < part_base + k_left else 1

    if len(vs) == g.vertex_count():
        sub_fixed = [side_pin(v) for v in range(g.vertex_count())]
        side = bisect(g, frac_left, sub_fixed, cfg, rng)
    else:
        sub_fixed = [side_pin(v) for v in vs]
        for i, v in enumerate(vs):
            remap[v] = i
        view = SubsetView(g, vs, remap)
        side = bisect(view, frac_left, sub_fixed, cfg, rng)
        for v in vs:
            remap[v] = None

    left = [vs[i] for i, s in enumerate(side) if s == 0]
    right = [vs[i] for i, s in enumerate(side) if s != 0]
    lt = [x / max(t_left, 1e-12) for x in targets[:k_left]]
    rt = [x / max(t_right, 1e-12) for x in targets[k_left:]]
    # Children draw from per-node derived PCG32 streams (mirrors
    # partition::child_rng) so the Rust side can fork the two recursions
    # onto scoped threads while staying bit-identical to this sequential
    # transliteration.
    lrng = child_rng(cfg["seed"], part_base, k_left)
    rrng = child_rng(cfg["seed"], part_base + k_left, k - k_left)
    recursive_bisect(g, left, lt, part_base, fixed, cfg, lrng, parts, remap)
    recursive_bisect(g, right, rt, part_base + k_left, fixed, cfg, rrng, parts, remap)


CHILD_STREAM = 0x9E3779B9


def child_rng(seed, part_base, k):
    """Mirror of partition::child_rng: the RNG of the recursion node that
    covers parts [part_base, part_base + k)."""
    return Pcg32(seed, CHILD_STREAM ^ ((part_base & M32) << 16) ^ k)


def partition(g, cfg):
    assert cfg["k"] >= 1
    n = g.vertex_count()
    if cfg["k"] == 1 or n == 0:
        parts = [0] * n
        return finish(g, parts, max(1, cfg["k"]))
    if cfg["targets"] is not None:
        assert len(cfg["targets"]) == cfg["k"]
        s = sum(cfg["targets"])
        targets = [x / s for x in cfg["targets"]]
    else:
        targets = [1.0 / cfg["k"]] * cfg["k"]
    fixed = cfg["fixed"] if cfg["fixed"] is not None else [-1] * n
    rng = Pcg32.seeded(cfg["seed"])
    parts = [0] * n
    remap = [None] * n
    recursive_bisect(g, list(range(n)), targets, 0, fixed, cfg, rng, parts, remap)
    return finish(g, parts, cfg["k"])


def finish(g, parts, k):
    return {
        "parts": parts,
        "edge_cut": edge_cut(g, parts),
        "part_weights": part_weights(g, parts, k),
    }


def _norm_targets(cfg):
    if cfg["targets"] is not None:
        assert len(cfg["targets"]) == cfg["k"]
        s = sum(cfg["targets"])
        return [x / s for x in cfg["targets"]]
    return [1.0 / cfg["k"]] * cfg["k"]


def partition_kway(g, cfg):
    """Mirror of partition::partition_kway_with: coarsen once with k-way
    pins, seed with recursive bisection on the coarsest graph, then direct
    k-way refinement at every uncoarsening level."""
    assert cfg["k"] >= 1
    n = g.vertex_count()
    if cfg["k"] == 1 or n == 0:
        return finish(g, [0] * n, max(1, cfg["k"]))
    targets = _norm_targets(cfg)
    fixed = cfg["fixed"] if cfg["fixed"] is not None else [-1] * n
    rng = Pcg32.seeded(cfg["seed"])
    until = max(cfg["coarsen_until"], 4 * cfg["k"])
    levels = []
    while True:
        cur_n = levels[-1].coarse.vertex_count() if levels else n
        if cur_n <= until:
            break
        if levels:
            lvl = coarsen_once(levels[-1].coarse, levels[-1].coarse_fixed, rng)
        else:
            lvl = coarsen_once(g, fixed, rng)
        if lvl.coarse.vertex_count() > 0.95 * cur_n:
            break
        levels.append(lvl)
    fg, ff = (levels[-1].coarse, levels[-1].coarse_fixed) if levels else (g, fixed)
    parts = kway_initial(fg, targets, ff, cfg)
    kway_refine(fg, parts, targets, ff, cfg)
    for i in range(len(levels) - 1, -1, -1):
        parts = levels[i].project(parts)
        fine, ffx = ((g, fixed) if i == 0
                     else (levels[i - 1].coarse, levels[i - 1].coarse_fixed))
        kway_refine(fine, parts, targets, ffx, cfg)
    return finish(g, parts, cfg["k"])


def kway_initial(cg, targets, fixed, cfg):
    """Mirror of partition::kway_initial."""
    n = cg.vertex_count()
    rng = Pcg32.seeded(cfg["seed"])
    parts = [0] * n
    remap = [None] * n
    recursive_bisect(cg, list(range(n)), targets, 0, fixed, cfg, rng, parts, remap)
    return parts


def partition_warm(g, cfg, warm):
    """Mirror of partition::partition_warm_with: warm assignment + one
    direct boundary refinement pass at the fine level, no multilevel
    work. warm[v] == -1 marks a *free* vertex (a frontier patch the
    previous assignment never covered, e.g. a newly admitted job): free
    vertices are placed greedily — balance band first, then
    connectivity, then relative load — before the refinement pass.
    The single pass is FM with rollback for k == 2 (matching the
    recursive-bisection reference's refinement strength) and the greedy
    k-way pass for k > 2."""
    assert cfg["k"] >= 1
    n = g.vertex_count()
    assert len(warm) == n
    if cfg["k"] == 1 or n == 0:
        return finish(g, [0] * n, max(1, cfg["k"]))
    targets = _norm_targets(cfg)
    fixed = cfg["fixed"] if cfg["fixed"] is not None else [-1] * n
    parts = [fixed[v] if fixed[v] >= 0
             else (min(warm[v], cfg["k"] - 1) if warm[v] >= 0 else -1)
             for v in range(n)]
    if any(p < 0 for p in parts):
        warm_place(g, parts, targets, cfg)
    one = dict(cfg, refine_passes=1)
    if cfg["k"] == 2:
        fm_refine(g, parts, targets[0], fixed, one, None)
    else:
        kway_refine(g, parts, targets, fixed, one)
    return finish(g, parts, cfg["k"])


def warm_place(g, parts, targets, cfg):
    """Mirror of partition::warm_place: greedy placement of free
    (parts[v] < 0) vertices in index order. Each vertex goes to the
    part minimizing (band-distance delta, -connectivity, projected
    relative load, p) — a fresh chain's head lands on the most
    underloaded device and its body follows via connectivity until the
    balance band pushes it elsewhere."""
    import math
    n = g.vertex_count()
    k = cfg["k"]
    total = g.total_vertex_weight()
    max_vw = max((g.vertex_weight(v) for v in range(n)), default=0)
    lo = []
    hi = []
    invt = []
    for p in range(k):
        tp = targets[p] * total
        lo.append(math.floor(tp - (cfg["epsilon"] * tp + max_vw)))
        hi.append(math.ceil(tp + (cfg["epsilon"] * tp + max_vw)))
        invt.append(1.0 / max(tp, 1e-12))

    def dist(p, x):
        return max(lo[p] - x, 0) + max(x - hi[p], 0)

    pwgts = [0] * k
    for v in range(n):
        if parts[v] >= 0:
            pwgts[parts[v]] += g.vertex_weight(v)
    conn = [0] * k
    for v in range(n):
        if parts[v] >= 0:
            continue
        for p in range(k):
            conn[p] = 0
        for (u, w) in g.neighbors(v):
            if w > 0 and parts[u] >= 0:
                conn[parts[u]] += w
        w = g.vertex_weight(v)
        best = None
        for p in range(k):
            dd = dist(p, pwgts[p] + w) - dist(p, pwgts[p])
            cand = (dd, -conn[p], (pwgts[p] + w) * invt[p], p)
            if best is None or cand < best:
                best = cand
        parts[v] = best[3]
        pwgts[best[3]] += w


# ------------------------------------------------- seed (old) algo mirror

def seed_fm_refine(g, side, frac0, fixed, cfg):
    """Mirror of the seed BinaryHeap FM (quality reference; heap tie
    order approximated with heapq on (-gain, -v))."""
    import math
    n = g.vertex_count()
    if n == 0:
        return 0
    total = g.total_vertex_weight()
    target0 = frac0 * total
    target1 = total - target0
    max_vw = max((g.vertex_weight(v) for v in range(n)), default=0)
    lo0 = math.floor(target0 - (cfg["epsilon"] * target0 + max_vw))
    hi0 = math.ceil(target0 + (cfg["epsilon"] * target1 + max_vw))
    cut = edge_cut(g, side)
    for _ in range(max(cfg["refine_passes"], 1)):
        improved, cut = seed_fm_pass(g, side, lo0, hi0, fixed, cut)
        if not improved:
            break
    return cut


def seed_fm_pass(g, side, lo0, hi0, fixed, cut):
    n = g.vertex_count()
    w0 = sum(g.vertex_weight(v) for v in range(n) if side[v] == 0)
    gain = [0] * n
    for v in range(n):
        gain[v] = sum(
            w if side[u] != side[v] else -w for (u, w) in g.neighbors(v)
        )
    heap = []
    for v in range(n):
        deg = g.xadj[v + 1] - g.xadj[v] if isinstance(g, MetisGraph) else None
        boundary = any(side[u] != side[v] for (u, _) in g.neighbors(v))
        if fixed[v] < 0 and (boundary or deg == 0):
            heapq.heappush(heap, (-gain[v], -v))
    locked = [fixed[v] >= 0 for v in range(n)]
    log = []
    running_cut = cut
    best_cut = cut
    best_len = 0
    w0_start = w0
    best_key = None

    def dist(w):
        if w < lo0:
            return lo0 - w
        if w > hi0:
            return w - hi0
        return 0

    abort_after = max(50, n // 100)
    while heap:
        ng, nv = heapq.heappop(heap)
        gv, v = -ng, -nv
        if len(log) >= best_len + abort_after:
            break
        if locked[v] or gv != gain[v]:
            continue
        new_w0 = w0 - g.vertex_weight(v) if side[v] == 0 else w0 + g.vertex_weight(v)
        if dist(new_w0) > 0 and dist(new_w0) >= dist(w0):
            continue
        if best_key is None:
            best_key = (dist(w0_start), cut)
        locked[v] = True
        side[v] = 1 - side[v]
        w0 = new_w0
        running_cut -= gv
        log.append(v)
        key = (dist(w0), running_cut)
        if key < best_key:
            best_key = key
            best_cut = running_cut
            best_len = len(log)
        for (u, w) in g.neighbors(v):
            if locked[u]:
                continue
            delta = -2 * w if side[u] == side[v] else 2 * w
            gain[u] += delta
            heapq.heappush(heap, (-gain[u], -u))
    for v in reversed(log[best_len:]):
        side[v] = 1 - side[v]
    improved = best_len > 0
    return improved, (best_cut if improved else cut)


def seed_bisect(g, frac0, fixed, cfg, rng):
    """Seed multilevel bisection: same coarsen/initial, heap FM."""
    n = g.vertex_count()
    if n == 0:
        return []
    total = g.total_vertex_weight()
    target0 = frac0 * total
    pos = [g.vertex_weight(v) for v in range(n) if g.vertex_weight(v) > 0]
    min_w = min(pos) if pos else 1
    if target0 < min_w / 2.0:
        return [0 if fixed[v] == 0 else 1 for v in range(n)]
    if (total - target0) < min_w / 2.0:
        return [1 if fixed[v] == 1 else 0 for v in range(n)]
    levels = []
    while True:
        cur_n = levels[-1].coarse.vertex_count() if levels else n
        if cur_n <= cfg["coarsen_until"]:
            break
        src = (levels[-1].coarse, levels[-1].coarse_fixed) if levels else (g, fixed)
        lvl = coarsen_once(src[0], src[1], rng)
        if lvl.coarse.vertex_count() > 0.95 * cur_n:
            break
        levels.append(lvl)
    fg, ff = (levels[-1].coarse, levels[-1].coarse_fixed) if levels else (g, fixed)
    side = greedy_growing(fg, frac0, ff, cfg, rng)
    seed_fm_refine(fg, side, frac0, ff, cfg)
    for i in range(len(levels) - 1, -1, -1):
        side = levels[i].project(side)
        fine = (g, fixed) if i == 0 else (levels[i - 1].coarse, levels[i - 1].coarse_fixed)
        seed_fm_refine(fine[0], side, frac0, fine[1], cfg)
    return side


def seed_partition2(g, cfg):
    """Seed k=2 partition (uniform targets) for quality comparison."""
    n = g.vertex_count()
    fixed = [-1] * n
    rng = Pcg32.seeded(cfg["seed"])
    side = seed_bisect(g, 0.5, fixed, cfg, rng)
    return finish(g, side, 2)


# ----------------------------------------------------------------- corpus

def two_cliques(sz, heavy, light):
    n = 2 * sz
    adj = [[] for _ in range(n)]
    for c in range(2):
        for i in range(sz):
            for j in range(sz):
                if i != j:
                    adj[c * sz + i].append((c * sz + j, heavy))
    adj[0].append((sz, light))
    adj[sz].append((0, light))
    return MetisGraph.from_adj([1] * n, adj)


def four_cliques(sz):
    n = 4 * sz
    adj = [[] for _ in range(n)]
    for c in range(4):
        for i in range(sz):
            for j in range(sz):
                if i != j:
                    adj[c * sz + i].append((c * sz + j, 20))
    for c in range(4):
        a = c * sz
        b = ((c + 1) % 4) * sz
        adj[a].append((b, 1))
        adj[b].append((a, 1))
    return MetisGraph.from_adj([1] * n, adj)


def path_graph(n, w):
    adj = [[] for _ in range(n)]
    for i in range(n - 1):
        adj[i].append((i + 1, w))
        adj[i + 1].append((i, w))
    return MetisGraph.from_adj([1] * n, adj)


def make_bench_graph(n, seed):
    import math
    cols = math.ceil(math.sqrt(n))
    adj = [[] for _ in range(n)]
    rng = Pcg32.seeded(seed)
    nbr = [set() for _ in range(n)]

    def add(a, b, w):
        if a != b and b not in nbr[a]:
            adj[a].append((b, w))
            adj[b].append((a, w))
            nbr[a].add(b)
            nbr[b].add(a)

    for v in range(n):
        if v + 1 < n and (v + 1) % cols != 0:
            add(v, v + 1, 10)
        if v + cols < n:
            add(v, v + cols, 10)
    for _ in range(n // 20):
        a = rng.gen_range(n)
        b = rng.gen_range(n)
        add(a, b, 1)
    return MetisGraph.from_adj([1] * n, adj)


def clique_ring(c, sz, heavy=20):
    """Ring of c cliques of sz unit-weight vertices (mirrors the Rust
    clique_ring test builder)."""
    n = c * sz
    adj = [[] for _ in range(n)]
    for q in range(c):
        for i in range(sz):
            for j in range(sz):
                if i != j:
                    adj[q * sz + i].append((q * sz + j, heavy))
    for q in range(c):
        a = q * sz
        b = ((q + 1) % c) * sz
        adj[a].append((b, 1))
        adj[b].append((a, 1))
    return MetisGraph.from_adj([1] * n, adj)


def ladder(n):
    """Two parallel paths with rungs, 2n unit vertices (mirrors the Rust
    refine.rs ladder test builder)."""
    adj = [[] for _ in range(2 * n)]

    def add(a, b):
        adj[a].append((b, 1))
        adj[b].append((a, 1))

    for i in range(n - 1):
        add(i, i + 1)
        add(n + i, n + i + 1)
    for i in range(n):
        add(i, n + i)
    return MetisGraph.from_adj([1] * (2 * n), adj)


def ring_cliques(k, size):
    """k cliques (weight-10 edges) ring-joined by single light edges at
    (c*size, next*size+1) — mirrors the refine.rs `cliques` builder."""
    n = k * size
    adj = [[] for _ in range(n)]
    for c in range(k):
        for i in range(size):
            for j in range(i + 1, size):
                a, b = c * size + i, c * size + j
                adj[a].append((b, 10))
                adj[b].append((a, 10))
        a = c * size
        b = ((c + 1) % k) * size + 1
        adj[a].append((b, 1))
        adj[b].append((a, 1))
    return MetisGraph.from_adj([1] * n, adj)


def check(name, cond, detail=""):
    status = "ok" if cond else "FAIL"
    print(f"  [{status}] {name} {detail}")
    return cond


def run_corpus():
    ok = True
    print("corpus: two_cliques(8,10,1)")
    g = two_cliques(8, 10, 1)
    res = partition(g, default_cfg())
    ok &= check("cut == 1", res["edge_cut"] == 1, f'(cut={res["edge_cut"]})')
    ok &= check("weights [8,8]", res["part_weights"] == [8, 8], str(res["part_weights"]))
    p = res["parts"]
    ok &= check(
        "cliques whole",
        all(x == p[0] for x in p[:8]) and all(x == p[8] for x in p[8:]) and p[0] != p[8],
    )

    print("corpus: degenerate target (0.001, 0.999)")
    res = partition(g, default_cfg(targets=[0.001, 0.999]))
    ok &= check(
        "all on side 1",
        res["part_weights"] == [0, 16] and res["edge_cut"] == 0,
        str(res["part_weights"]),
    )

    print("corpus: weighted_targets path(30) 1:2")
    g = path_graph(30, 1)
    res = partition(g, default_cfg(targets=[1 / 3, 2 / 3]))
    f0 = res["part_weights"][0] / 30
    ok &= check("fraction ~1/3", abs(f0 - 1 / 3) < 0.12, f"(f0={f0:.3f})")
    ok &= check("cut <= 3", res["edge_cut"] <= 3, f'(cut={res["edge_cut"]})')

    print("corpus: kway_four_cliques k=4 seed=3")
    g = four_cliques(6)
    res = partition(g, default_cfg(k=4, seed=3))
    ok &= check("weights [6,6,6,6]", res["part_weights"] == [6] * 4, str(res["part_weights"]))
    ok &= check("cut <= 4", res["edge_cut"] <= 4, f'(cut={res["edge_cut"]})')
    for c in range(4):
        p0 = res["parts"][c * 6]
        ok &= check(f"clique {c} uniform", all(res["parts"][c * 6 + i] == p0 for i in range(6)))

    print("corpus: determinism (seed 42)")
    g = two_cliques(10, 5, 1)
    a = partition(g, default_cfg(seed=42))
    b = partition(g, default_cfg(seed=42))
    ok &= check("identical parts", a["parts"] == b["parts"])

    print("corpus: pins through views (k=3)")
    g = two_cliques(9, 6, 1)
    fixed = [-1] * 18
    fixed[0] = 2
    fixed[17] = 0
    res = partition(g, default_cfg(k=3, seed=5, fixed=fixed))
    ok &= check("pin v0 -> 2", res["parts"][0] == 2, f'(got {res["parts"][0]})')
    ok &= check("pin v17 -> 0", res["parts"][17] == 0, f'(got {res["parts"][17]})')

    print("corpus: random-graph invariants (forall_partitions_consistent)")
    rng = Pcg32.seeded(0xD00D)
    for trial in range(12):
        n = rng.gen_range_usize(1, 400)
        adj = [[] for _ in range(n)]
        for v in range(1, n):
            u = rng.gen_range_usize(0, v)
            w = 1 + rng.gen_range(20)
            adj[v].append((u, w))
            adj[u].append((v, w))
        for _ in range(n // 2):
            a = rng.gen_range_usize(0, n)
            b = rng.gen_range_usize(0, n)
            if a != b and all(x != b for (x, _) in adj[a]):
                w = 1 + rng.gen_range(20)
                adj[a].append((b, w))
                adj[b].append((a, w))
        vwgt = [1 + rng.gen_range(9) for _ in range(n)]
        g = MetisGraph.from_adj(vwgt, adj)
        k = rng.gen_range_usize(1, min(5, n + 1))
        if rng.gen_bool(0.5):
            raw = [0.05 + rng.gen_f64() for _ in range(k)]
            s = sum(raw)
            targets = [x / s for x in raw]
        else:
            targets = None
        cfg = default_cfg(k=k, targets=targets, seed=rng.next_u64())
        res = partition(g, cfg)
        ok &= (
            len(res["parts"]) == n
            and all(p < k for p in res["parts"])
            and res["edge_cut"] == edge_cut(g, res["parts"])
            and res["part_weights"] == part_weights(g, res["parts"], k)
            and sum(res["part_weights"]) == sum(vwgt)
        )
    print(f"  [{'ok' if ok else 'FAIL'}] 12 random trials")
    ok &= run_kway_checks()
    return ok


def run_kway_checks():
    """Checks for the direct k-way refinement + warm-start paths,
    replicating the Rust unit tests in refine.rs / partition/mod.rs so a
    mirror pass predicts the Rust test outcomes."""
    ok = True
    import math

    print("kway: two-way refinement on a bad ladder partition")
    g = ladder(8)
    parts = [v % 2 for v in range(16)]
    before = edge_cut(g, parts)
    after = kway_refine(g, parts, [0.5, 0.5], [-1] * 16, default_cfg())
    ok &= check("cut improves", after < before, f"({before} -> {after})")
    ok &= check("cut consistent", after == edge_cut(g, parts))
    w0 = sum(1 for p in parts if p == 0)
    ok &= check("balance", 6 <= w0 <= 10, f"(w0={w0})")

    print("kway: restores perturbed optimum (4 cliques of 6)")
    g = ring_cliques(4, 6)
    optimal_parts = [v // 6 for v in range(24)]
    optimal = edge_cut(g, optimal_parts)
    parts = list(optimal_parts)
    for c in range(4):
        parts[c * 6 + 2] = (c + 1) % 4
    after = kway_refine(g, parts, [0.25] * 4, [-1] * 24, default_cfg())
    ok &= check("optimal cut restored", after == optimal, f"({after} vs {optimal})")
    ok &= check("optimal parts restored", parts == optimal_parts)

    print("kway: restores balance from degenerate all-in-one assignment")
    g = ladder(9)
    parts = [0] * 18
    after = kway_refine(g, parts, [1 / 3] * 3, [-1] * 18, default_cfg())
    ok &= check("cut consistent", after == edge_cut(g, parts))
    for p in range(3):
        w = sum(1 for q in parts if q == p)
        ok &= check(f"part {p} in band", 4 <= w <= 8, f"(w={w})")

    print("kway: pinned vertices never move")
    g = ring_cliques(3, 4)
    parts = [v // 4 for v in range(12)]
    parts[1] = 1
    parts[5] = 2
    fixed = [-1] * 12
    fixed[1] = 1
    fixed[5] = 2
    after = kway_refine(g, parts, [1 / 3] * 3, fixed, default_cfg())
    ok &= check("pins kept", parts[1] == 1 and parts[5] == 2)
    ok &= check("cut consistent", after == edge_cut(g, parts))

    print("kway-direct: cut parity vs recursive bisection on the corpus")
    print(f"  {'graph':>22} {'k':>3} {'bisect':>8} {'kway':>8} {'ratio':>7}")
    parity_ok = True
    worst = 0.0
    corpus = [
        ("clique_ring(4,6)", clique_ring(4, 6), 4, 3),
        ("clique_ring(4,30)", clique_ring(4, 30), 4, 7),
        ("clique_ring(8,16)", clique_ring(8, 16), 8, 11),
        ("four_cliques(6)", four_cliques(6), 4, 3),
        ("two_cliques(8,10,1)", two_cliques(8, 10, 1), 2, 1),
        ("bench(400)", make_bench_graph(400, 3), 4, 5),
        ("bench(2000)", make_bench_graph(2000, 3), 4, 5),
        ("bench(2000) k=8", make_bench_graph(2000, 4), 8, 9),
    ]
    for (name, g, k, seed) in corpus:
        cfg = default_cfg(k=k, seed=seed)
        scratch = partition(g, cfg)
        direct = partition_kway(g, cfg)
        ratio = direct["edge_cut"] / max(scratch["edge_cut"], 1)
        worst = max(worst, ratio)
        legal = (all(p < k for p in direct["parts"])
                 and direct["edge_cut"] == edge_cut(g, direct["parts"]))
        parity_ok &= legal
        print(f"  {name:>22} {k:>3} {scratch['edge_cut']:>8} "
              f"{direct['edge_cut']:>8} {ratio:>7.3f}")
    ok &= check("kway-direct legal everywhere", parity_ok)
    # Greedy no-rollback k-way refinement trades some cut on unstructured
    # grids for eliminating the log-k full-edge-array descents; on
    # structured (clique) corpus graphs parity is exact (asserted below).
    ok &= check("kway-direct worst ratio <= 1.5", worst <= 1.5,
                f"(worst={worst:.3f})")
    for (c, sz, seed) in [(4, 6, 3), (4, 30, 7), (8, 16, 11)]:
        g = clique_ring(c, sz)
        cfg = default_cfg(k=c, seed=seed)
        a = partition(g, cfg)
        b = partition_kway(g, cfg)
        ok &= check(
            f"clique_ring({c},{sz}) exact parity",
            b["edge_cut"] == a["edge_cut"] and b["part_weights"] == a["part_weights"],
            f'(bisect={a["edge_cut"]}, kway={b["edge_cut"]})',
        )

    print("warm-start: recovers perturbed plan (clique_ring(4,8) seed 9)")
    g = clique_ring(4, 8)
    cfg = default_cfg(k=4, seed=9)
    scratch = partition(g, cfg)
    warm = list(scratch["parts"])
    for c in range(4):
        warm[c * 8 + 3] = (warm[c * 8 + 3] + 1) % 4
    res = partition_warm(g, cfg, warm)
    ok &= check("scratch cut recovered", res["edge_cut"] == scratch["edge_cut"],
                f'({res["edge_cut"]} vs {scratch["edge_cut"]})')
    ok &= check("weights match", res["part_weights"] == scratch["part_weights"])

    print("warm-start: pins override warm vector (clique_ring(3,6) seed 4)")
    g = clique_ring(3, 6)
    fixed = [-1] * 18
    fixed[4] = 2
    cfg = default_cfg(k=3, seed=4, fixed=fixed)
    res = partition_warm(g, cfg, [0] * 18)
    ok &= check("pin honored", res["parts"][4] == 2)
    ok &= check("legal", all(p < 3 for p in res["parts"]))
    total = sum(res["part_weights"])
    band_ok = True
    for p, w in enumerate(res["part_weights"]):
        t = total / 3.0
        hi = math.ceil(t + default_cfg()["epsilon"] * t + 1.0)
        band_ok &= w <= hi
    ok &= check("bands respected", band_ok, str(res["part_weights"]))

    print("warm-start: out-of-range entries clamped (two_cliques(6,8,1))")
    g = two_cliques(6, 8, 1)
    cfg = default_cfg(k=2, seed=2)
    res = partition_warm(g, cfg, [v % 5 for v in range(12)])
    ok &= check("legal", all(p < 2 for p in res["parts"]))
    ok &= check("cut consistent", res["edge_cut"] == edge_cut(g, res["parts"]))

    print("warm-start property: PCG32-random frontier diffs stay legal + close")
    # Simulates the incremental-replan lifecycle on random graphs: scratch
    # partition -> random frontier diff (drop a random prefix of vertices,
    # append fresh ones) -> warm-start on the patched graph vs scratch on
    # the patched graph. The warm result must always be legal, and its cut
    # within tolerance of from-scratch.
    rng = Pcg32.seeded(0xFACE)
    worst_ratio = 0.0
    prop_ok = True
    for trial in range(10):
        n = rng.gen_range_usize(40, 300)
        k = rng.gen_range_usize(2, 5)
        g0 = make_bench_graph(n, rng.next_u64() & 0xFFFF)
        cfg = default_cfg(k=k, seed=rng.next_u64() & 0xFFFF)
        base = partition(g0, cfg)
        # Frontier diff, as the gp replan patches it: a prefix of
        # vertices completes (dropped), survivors keep their edges
        # (reindexed), and newly-submitted vertices append with random
        # edges into the existing frontier.
        drop = rng.gen_range_usize(1, n // 3)
        grow = rng.gen_range_usize(1, n // 3)
        keep = list(range(drop, n))
        local = {v: i for i, v in enumerate(keep)}
        n1 = len(keep) + grow
        adj = [[] for _ in range(n1)]
        for v in keep:
            for (u, w) in g0.neighbors(v):
                lu = local.get(u)
                if lu is not None and lu > local[v]:
                    adj[local[v]].append((lu, w))
                    adj[lu].append((local[v], w))
        for i in range(grow):
            nv = len(keep) + i
            for _ in range(1 + rng.gen_range(3)):
                u = rng.gen_range_usize(0, nv)
                w = 1 + rng.gen_range(10)
                if all(x != u for (x, _) in adj[nv]):
                    adj[nv].append((u, w))
                    adj[u].append((nv, w))
        g1 = MetisGraph.from_adj([1] * n1, adj)
        warm = [base["parts"][v] for v in keep] + [0] * grow
        res = partition_warm(g1, cfg, warm)
        scr = partition(g1, cfg)
        legal = (all(p < k for p in res["parts"])
                 and res["edge_cut"] == edge_cut(g1, res["parts"]))
        prop_ok &= legal
        ratio = res["edge_cut"] / max(scr["edge_cut"], 1)
        worst_ratio = max(worst_ratio, ratio)
    ok &= check("10 random diffs legal", prop_ok)
    ok &= check("warm cut within 1.35x of scratch on random diffs",
                worst_ratio <= 1.35, f"(worst={worst_ratio:.3f})")

    print("rust-test replica: warm_start_random_frontier_diffs_stay_legal_and_close")
    # Bit-exact transliteration of the Rust unit test (same PCG32 seed,
    # same draw order) so the committed test is validated here despite the
    # container lacking a Rust toolchain.
    rng = Pcg32.seeded(0xFACE)
    rust_ok = True
    for _trial in range(6):
        n = rng.gen_range_usize(40, 200)
        k = rng.gen_range_usize(2, 5)
        adj = [[] for _ in range(n)]
        for v in range(1, n):
            u = rng.gen_range_usize(0, v)
            w = 1 + rng.gen_range(20)
            adj[v].append((u, w))
            adj[u].append((v, w))
        for _ in range(n // 2):
            a = rng.gen_range_usize(0, n)
            b = rng.gen_range_usize(0, n)
            if a != b and all(x != b for (x, _) in adj[a]):
                w = 1 + rng.gen_range(20)
                adj[a].append((b, w))
                adj[b].append((a, w))
        g0 = MetisGraph.from_adj([1] * n, adj)
        cfg = default_cfg(k=k, seed=rng.next_u64())
        base = partition(g0, cfg)
        drop = rng.gen_range_usize(1, n // 3)
        grow = rng.gen_range_usize(1, n // 3)
        n1 = n - drop + grow
        adj1 = [[] for _ in range(n1)]
        for v in range(drop, n):
            for (u, w) in adj[v]:
                if u >= drop and u > v:
                    adj1[v - drop].append((u - drop, w))
                    adj1[u - drop].append((v - drop, w))
        for i in range(grow):
            nv = n - drop + i
            for _ in range(1 + rng.gen_range(3)):
                u = rng.gen_range_usize(0, nv)
                w = 1 + rng.gen_range(10)
                if all(x != u for (x, _) in adj1[nv]):
                    adj1[nv].append((u, w))
                    adj1[u].append((nv, w))
        g1 = MetisGraph.from_adj([1] * n1, adj1)
        warm = [base["parts"][v] for v in range(drop, n)] + [0] * grow
        res = partition_warm(g1, cfg, warm)
        scr = partition(g1, cfg)
        rust_ok &= all(p < k for p in res["parts"])
        rust_ok &= res["edge_cut"] == edge_cut(g1, res["parts"])
        rust_ok &= res["part_weights"] == part_weights(g1, res["parts"], k)
        rust_ok &= res["edge_cut"] <= scr["edge_cut"] * 4 + 16
    ok &= check("6 rust-test trials legal + within 4x+16", rust_ok)
    return ok


def run_bench():
    print("quality + relative-work comparison, new (bucket) vs seed (heap):")
    print(f"{'n':>8} {'seed_cut':>9} {'new_cut':>9} {'ratio':>7} "
          f"{'seed_s':>8} {'new_s':>8} {'rnd_cut':>9}")
    rows = []
    for n in [100, 1000, 10000, 100000]:
        g = make_bench_graph(n, 3)
        t0 = time.time()
        old = seed_partition2(g, default_cfg())
        t_old = time.time() - t0
        t0 = time.time()
        new = partition(g, default_cfg())
        t_new = time.time() - t0
        rng = Pcg32.seeded(99)
        rparts = [rng.gen_range(2) for _ in range(n)]
        rnd = max(edge_cut(g, rparts), 1)
        ratio = new["edge_cut"] / max(old["edge_cut"], 1)
        rows.append((n, g.edge_count(), old["edge_cut"], new["edge_cut"], ratio,
                     t_old, t_new, rnd))
        print(f"{n:>8} {old['edge_cut']:>9} {new['edge_cut']:>9} {ratio:>7.3f} "
              f"{t_old:>8.2f} {t_new:>8.2f} {rnd:>9}")
        assert new["edge_cut"] < rnd / 4, f"new cut must beat random/4 at n={n}"
    return rows


def emit_json(rows, path):
    """Write the mirror's before/after evidence in (approximately) the
    schema `cargo bench --bench partitioner` emits; running the real
    bench overwrites this file with measured Rust wall times."""
    lines = [
        "{",
        '  "bench": "partitioner",',
        '  "harness": "python-mirror (build container has no Rust toolchain; '
        'cut values are exact algorithm outputs, *_python_s are Python mirror '
        "wall seconds — regenerate with `cargo bench --bench partitioner` for "
        'Rust wall-ms)",',
        '  "scaling": [',
    ]
    for i, (n, edges, seed_cut, new_cut, ratio, t_old, t_new, rnd) in enumerate(rows):
        sep = "," if i + 1 < len(rows) else ""
        lines.append(
            f'    {{"n": {n}, "edges": {edges}, "seed_cut": {seed_cut}, '
            f'"cut": {new_cut}, "cut_vs_seed_ratio": {ratio:.4f}, '
            f'"cut_random_ratio": {new_cut / rnd:.4f}, '
            f'"seed_python_s": {t_old:.2f}, "csr_python_s": {t_new:.2f}}}{sep}'
        )
    lines += ["  ]", "}", ""]
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "bench":
        run_bench()
    elif len(sys.argv) > 1 and sys.argv[1] == "json":
        rows = run_bench()
        emit_json(rows, sys.argv[2] if len(sys.argv) > 2
                  else "rust/bench_results/BENCH_partitioner.json")
    else:
        ok = run_corpus()
        print("ALL OK" if ok else "FAILURES PRESENT")
        sys.exit(0 if ok else 1)
